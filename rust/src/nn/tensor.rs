//! Minimal dense linear algebra for the native (pure-Rust) backend.
//!
//! Row-major `Mat` plus the handful of ops an MLP needs: matmul with
//! optional operand transposes, bias add, activations. The compute
//! itself lives in [`crate::nn::kernels`] — arch-dispatched slice
//! kernels (scalar / AVX2 / NEON) selected once at startup; this module
//! is the `Mat`-typed veneer the MLP and tests use.

use crate::nn::kernels;

/// Row-major 2-D matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_rows(rows: &[&[f32]]) -> Mat {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat {
            rows: r,
            cols: c,
            data,
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// out = a @ b. a:[m,k] b:[k,n] -> [m,n].
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = Mat::zeros(m, n);
    kernels::matmul(&a.data, &b.data, &mut out.data, m, k, n);
    out
}

/// out = a^T @ b. a:[k,m] b:[k,n] -> [m,n] (no explicit transpose alloc).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn dim mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut out = Mat::zeros(m, n);
    kernels::matmul_tn(&a.data, &b.data, &mut out.data, m, k, n);
    out
}

/// out = a @ b^T. a:[m,k] b:[n,k] -> [m,n].
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut out = Mat::zeros(m, n);
    kernels::matmul_nt(&a.data, &b.data, &mut out.data, m, k, n);
    out
}

/// y += bias (bias broadcast over rows).
pub fn add_bias(y: &mut Mat, bias: &[f32]) {
    assert_eq!(bias.len(), y.cols);
    kernels::add_bias(&mut y.data, bias, y.rows, y.cols);
}

/// Supported fused activations (mirror of python kernels/ref.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Id,
    Tanh,
    Relu,
}

pub fn apply_act(y: &mut Mat, act: Act) {
    match act {
        Act::Id => {}
        Act::Tanh => kernels::tanh_inplace(&mut y.data),
        Act::Relu => kernels::relu_inplace(&mut y.data),
    }
}

/// d(act)/d(pre) expressed from the *output* (same trick as the Pallas
/// backward): tanh' = 1 - y^2, relu' = [y>0], id' = 1.
pub fn act_grad_from_out(y: &Mat, act: Act) -> Mat {
    let mut g = Mat::zeros(y.rows, y.cols);
    match act {
        Act::Id => g.data.fill(1.0),
        Act::Tanh => {
            for (o, &v) in g.data.iter_mut().zip(&y.data) {
                *o = 1.0 - v * v;
            }
        }
        Act::Relu => {
            for (o, &v) in g.data.iter_mut().zip(&y.data) {
                *o = if v > 0.0 { 1.0 } else { 0.0 };
            }
        }
    }
    g
}

/// Column sums (bias gradient). y:[m,n] -> [n].
pub fn col_sums(y: &Mat) -> Vec<f32> {
    let mut out = vec![0.0; y.cols];
    for r in 0..y.rows {
        for (o, &v) in out.iter_mut().zip(y.row(r)) {
            *o += v;
        }
    }
    out
}

/// Element-wise product in place: a *= b.
pub fn mul_inplace(a: &mut Mat, b: &Mat) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x *= y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: &Mat, b: &Mat, tol: f32) {
        assert!(a.max_abs_diff(b) < tol, "\n{a:?}\nvs\n{b:?}");
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = matmul(&a, &b);
        approx(&c, &Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]), 1e-6);
    }

    #[test]
    fn transposed_variants_agree_with_explicit_transpose() {
        let mut rng = crate::util::rng::Pcg64::new(0);
        let a = Mat::from_vec(7, 5, (0..35).map(|_| rng.normal()).collect());
        let b = Mat::from_vec(7, 4, (0..28).map(|_| rng.normal()).collect());
        approx(&matmul_tn(&a, &b), &matmul(&a.t(), &b), 1e-5);
        let c = Mat::from_vec(6, 5, (0..30).map(|_| rng.normal()).collect());
        approx(&matmul_nt(&a, &c), &matmul(&a, &c.t()), 1e-5);
    }

    #[test]
    fn bias_and_activations() {
        let mut y = Mat::from_rows(&[&[-1.0, 0.0], &[2.0, -3.0]]);
        add_bias(&mut y, &[1.0, 1.0]);
        let mut relu = y.clone();
        apply_act(&mut relu, Act::Relu);
        approx(&relu, &Mat::from_rows(&[&[0.0, 1.0], &[3.0, 0.0]]), 1e-6);
        let mut tanh = y.clone();
        apply_act(&mut tanh, Act::Tanh);
        assert!((tanh.at(1, 0) - 3.0f32.tanh()).abs() < 1e-6);
    }

    #[test]
    fn act_grads_from_output() {
        let y = Mat::from_rows(&[&[0.5, -0.5]]);
        let g = act_grad_from_out(&y, Act::Tanh);
        assert!((g.at(0, 0) - 0.75).abs() < 1e-6);
        let g = act_grad_from_out(&y, Act::Relu);
        assert_eq!(g.data, vec![1.0, 0.0]);
        let g = act_grad_from_out(&y, Act::Id);
        assert_eq!(g.data, vec![1.0, 1.0]);
    }

    #[test]
    fn col_sums_known() {
        let y = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(col_sums(&y), vec![4.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "matmul dim mismatch")]
    fn matmul_rejects_bad_shapes() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        matmul(&a, &b);
    }
}
