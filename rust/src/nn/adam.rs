//! Native Adam optimizer over a flat f32 vector — the exact mirror of the
//! L1 `adam_step` Pallas kernel (`python/compile/kernels/adam.py`), used by
//! the `NativeBackend` and as the oracle in XLA-vs-native parity tests.

/// Adam hyper-parameters (defaults match the AOT artifacts).
#[derive(Debug, Clone, Copy)]
pub struct AdamCfg {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamCfg {
    fn default() -> Self {
        Self {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }
}

/// Adam state for one flat parameter vector.
#[derive(Debug, Clone)]
pub struct Adam {
    pub cfg: AdamCfg,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u64,
}

impl Adam {
    pub fn new(n: usize, cfg: AdamCfg) -> Self {
        Self {
            cfg,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    /// In-place update of `params` with gradient `grad`; increments t.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let t = self.t as f32;
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powf(t);
        let bc2 = 1.0 - b2.powf(t);
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + self.cfg.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_grad_is_noop() {
        let mut adam = Adam::new(4, AdamCfg::default());
        let mut p = vec![1.0, -2.0, 3.0, 0.5];
        let orig = p.clone();
        adam.step(&mut p, &[0.0; 4], 1e-3);
        for (a, b) in p.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn descends_quadratic() {
        // f(p) = ||p||^2, grad = 2p
        let mut adam = Adam::new(8, AdamCfg::default());
        let mut p: Vec<f32> = (0..8).map(|i| (i as f32 - 3.5) * 0.5).collect();
        let start: f32 = p.iter().map(|x| x * x).sum();
        for _ in 0..300 {
            let g: Vec<f32> = p.iter().map(|x| 2.0 * x).collect();
            adam.step(&mut p, &g, 0.05);
        }
        let end: f32 = p.iter().map(|x| x * x).sum();
        assert!(end < 0.01 * start, "start={start} end={end}");
    }

    #[test]
    fn first_step_size_is_lr() {
        // with bias correction, |Δp| of the very first step ≈ lr
        let mut adam = Adam::new(1, AdamCfg::default());
        let mut p = vec![0.0f32];
        adam.step(&mut p, &[123.0], 1e-2);
        assert!((p[0].abs() - 1e-2).abs() < 1e-4, "p={}", p[0]);
    }

    #[test]
    fn matches_reference_formula() {
        // hand-rolled single-step reference (same formula as kernels/ref.py)
        let cfg = AdamCfg::default();
        let mut adam = Adam::new(3, cfg);
        let mut p = vec![1.0f32, -1.0, 0.2];
        let g = vec![0.3f32, -0.1, 0.7];
        let lr = 3e-4;
        let want: Vec<f32> = p
            .iter()
            .zip(&g)
            .map(|(&pi, &gi)| {
                let m = (1.0 - cfg.beta1) * gi;
                let v = (1.0 - cfg.beta2) * gi * gi;
                let mhat = m / (1.0 - cfg.beta1);
                let vhat = v / (1.0 - cfg.beta2);
                pi - lr * mhat / (vhat.sqrt() + cfg.eps)
            })
            .collect();
        adam.step(&mut p, &g, lr);
        for (a, b) in p.iter().zip(&want) {
            assert!((a - b).abs() < 1e-7);
        }
    }
}
