//! int8 quantized actor snapshots for the inference hot path.
//!
//! The learner stays f32; quantization happens once per policy publish
//! (`PolicyStore::publish` with a quantizer installed), producing a
//! [`QuantizedPolicySnapshot`] that rides inside the regular
//! `PolicySnapshot` broadcast — the EpochGate propose/ack/flip machinery
//! ships it to every inference shard for free, so all shards flip to the
//! same quantized weights on the same epoch boundary.
//!
//! Scheme (see `nn::kernels` module docs for the integer contract):
//! weights are symmetric per-output-column int8 (`quantize_cols`),
//! activations are quantized per-row at call time (`quantize_rows`,
//! dynamic range per observation), accumulation is exact i32, and the
//! dequant epilogue applies `ascale[i]*wscale[j]` then adds the f32 bias.
//! Biases and `log_std` stay f32 — they are tiny and precision-critical.
//!
//! The forward math mirrors `nn::mlp` exactly (same layer order, same
//! activations, same Gaussian logp formula) so the quantized path is a
//! drop-in for the server actor: only the GEMM arithmetic differs.

use crate::nn::kernels;
use crate::nn::layout::ParamLayout;
use crate::nn::mlp::{NetShape, LOG_2PI};
use crate::nn::tensor::Act;

/// One dense layer with int8 weights: `y = act(x @ wq·scales + bias)`.
#[derive(Debug, Clone)]
pub struct QuantLinear {
    pub k: usize,
    pub n: usize,
    /// [k, n] row-major int8 weights (symmetric, per-column scales).
    pub wq: Vec<i8>,
    /// Per-output-column dequant scales, len n.
    pub wscale: Vec<f32>,
    /// f32 bias, len n.
    pub bias: Vec<f32>,
    pub act: Act,
}

impl QuantLinear {
    fn from_params(w: &[f32], bias: &[f32], k: usize, n: usize, act: Act) -> QuantLinear {
        let mut wq = vec![0i8; k * n];
        let mut wscale = vec![0.0f32; n];
        kernels::quantize_cols(w, k, n, &mut wq, &mut wscale);
        QuantLinear {
            k,
            n,
            wq,
            wscale,
            bias: bias.to_vec(),
            act,
        }
    }
}

/// A whole MLP in int8 (hidden layers + output layer, in order).
#[derive(Debug, Clone)]
pub struct QuantMlp {
    pub layers: Vec<QuantLinear>,
}

impl QuantMlp {
    /// Quantize the `prefix` MLP out of a flat f32 parameter vector
    /// (same naming scheme as `nn::mlp::mlp_forward`).
    pub fn from_layout(
        layout: &ParamLayout,
        flat: &[f32],
        prefix: &str,
        n_hidden: usize,
        hidden_act: Act,
        out_act: Act,
    ) -> QuantMlp {
        let mut layers = Vec::with_capacity(n_hidden + 1);
        for i in 0..=n_hidden {
            let name = if i < n_hidden {
                format!("{prefix}/l{i}")
            } else {
                format!("{prefix}/out")
            };
            let we = layout
                .find(&format!("{name}/w"))
                .unwrap_or_else(|| panic!("missing param {name}/w"));
            let w = &flat[we.offset..we.offset + we.size()];
            let (k, n) = (we.shape[0], we.shape[1]);
            let bias = layout.view(flat, &format!("{name}/b")).unwrap();
            let act = if i < n_hidden { hidden_act } else { out_act };
            layers.push(QuantLinear::from_params(w, bias, k, n, act));
        }
        QuantMlp { layers }
    }

    pub fn in_dim(&self) -> usize {
        self.layers.first().map_or(0, |l| l.k)
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().map_or(0, |l| l.n)
    }

    /// Batched forward: x is [rows, in_dim] row-major; returns
    /// [rows, out_dim]. Activations are re-quantized per layer (dynamic
    /// per-row scales), GEMM+dequant+bias is one fused `matmul_q8`.
    pub fn forward(&self, x: &[f32], rows: usize) -> Vec<f32> {
        assert_eq!(x.len(), rows * self.in_dim(), "quant forward: bad x len");
        let mut cur = x.to_vec();
        let mut qbuf: Vec<i8> = Vec::new();
        let mut scales = vec![0.0f32; rows];
        for layer in &self.layers {
            qbuf.resize(rows * layer.k, 0);
            kernels::quantize_rows(&cur, rows, layer.k, &mut qbuf, &mut scales);
            let mut y = vec![0.0f32; rows * layer.n];
            kernels::matmul_q8(
                &qbuf,
                &scales,
                &layer.wq,
                &layer.wscale,
                &layer.bias,
                &mut y,
                rows,
                layer.k,
                layer.n,
            );
            match layer.act {
                Act::Id => {}
                Act::Relu => kernels::relu_inplace(&mut y),
                Act::Tanh => kernels::tanh_inplace(&mut y),
            }
            cur = y;
        }
        cur
    }
}

/// Output of one quantized stochastic forward (mirror of `mlp::ActOut`,
/// flat row-major slices instead of `Mat`).
#[derive(Debug, Clone)]
pub struct QuantActOut {
    pub action: Vec<f32>,
    pub logp: Vec<f32>,
    pub value: Vec<f32>,
    pub mean: Vec<f32>,
}

/// An actor network quantized at publish time. For PPO this holds the
/// policy mean MLP, the value MLP, and the f32 `log_std`; for DDPG/TD3
/// only the deterministic actor (`vf == None`, `log_std` empty).
#[derive(Debug, Clone)]
pub struct QuantizedPolicySnapshot {
    pub obs_dim: usize,
    pub act_dim: usize,
    pub pi: QuantMlp,
    pub vf: Option<QuantMlp>,
    /// f32 state-independent log-std (PPO only; empty for deterministic).
    pub log_std: Vec<f32>,
}

/// Quantize a PPO policy (pi mean MLP + vf MLP + log_std) from its flat
/// f32 parameter vector.
pub fn quantize_ppo(layout: &ParamLayout, flat: &[f32], shape: &NetShape) -> QuantizedPolicySnapshot {
    let nh = shape.hidden.len();
    let pi = QuantMlp::from_layout(layout, flat, "pi", nh, Act::Tanh, Act::Id);
    let vf = QuantMlp::from_layout(layout, flat, "vf", nh, Act::Tanh, Act::Id);
    let log_std = layout.view(flat, "pi/log_std").unwrap().to_vec();
    QuantizedPolicySnapshot {
        obs_dim: shape.obs_dim,
        act_dim: shape.act_dim,
        pi,
        vf: Some(vf),
        log_std,
    }
}

/// Quantize a deterministic DDPG/TD3 actor (relu hidden, tanh output).
pub fn quantize_det_actor(
    layout: &ParamLayout,
    flat: &[f32],
    shape: &NetShape,
) -> QuantizedPolicySnapshot {
    let nh = shape.hidden.len();
    let pi = QuantMlp::from_layout(layout, flat, "actor", nh, Act::Relu, Act::Tanh);
    QuantizedPolicySnapshot {
        obs_dim: shape.obs_dim,
        act_dim: shape.act_dim,
        pi,
        vf: None,
        log_std: Vec::new(),
    }
}

impl QuantizedPolicySnapshot {
    /// Stochastic act (PPO server path): `action = mean + std * noise`,
    /// diagonal-Gaussian logp, value head. Same math as `mlp::act` with
    /// the exp/constant hoists.
    pub fn forward_stochastic(&self, obs: &[f32], noise: &[f32]) -> QuantActOut {
        let rows = obs.len() / self.obs_dim;
        assert_eq!(obs.len(), rows * self.obs_dim, "quant act: bad obs len");
        assert_eq!(noise.len(), rows * self.act_dim, "quant act: bad noise len");
        let a = self.act_dim;
        let mean = self.pi.forward(obs, rows);
        let value = self
            .vf
            .as_ref()
            .map_or_else(|| vec![0.0; rows], |vf| vf.forward(obs, rows));
        let std: Vec<f32> = self.log_std.iter().map(|ls| ls.exp()).collect();
        let inv_std: Vec<f32> = self.log_std.iter().map(|ls| (-ls).exp()).collect();
        let base: f32 = self.log_std.iter().map(|ls| -ls - 0.5 * LOG_2PI).sum();
        let mut action = mean.clone();
        let mut logp = vec![0.0f32; rows];
        for r in 0..rows {
            let arow = &mut action[r * a..(r + 1) * a];
            let nrow = &noise[r * a..(r + 1) * a];
            let mut acc = 0.0f32;
            for c in 0..a {
                arow[c] += std[c] * nrow[c];
                // z = (action - mean) / std = noise * std * inv_std; computed
                // from the stored values to match mlp::gaussian_logp exactly
                let z = (arow[c] - mean[r * a + c]) * inv_std[c];
                acc += -0.5 * z * z;
            }
            logp[r] = acc + base;
        }
        QuantActOut {
            action,
            logp,
            value,
            mean,
        }
    }

    /// Deterministic act (DDPG/TD3 server path).
    pub fn forward_deterministic(&self, obs: &[f32]) -> Vec<f32> {
        let rows = obs.len() / self.obs_dim;
        assert_eq!(obs.len(), rows * self.obs_dim, "quant act: bad obs len");
        self.pi.forward(obs, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layout::{actor_layout, ppo_layout};
    use crate::nn::mlp::{self, NetShape};
    use crate::nn::tensor::Mat;
    use crate::util::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_normal(&mut m.data);
        m
    }

    /// int8 PPO forward tracks the f32 oracle within quantization error.
    #[test]
    fn quantized_ppo_tracks_f32_forward() {
        let shape = NetShape::new(5, 3, &[32, 32]);
        let layout = ppo_layout(5, 3, &[32, 32]);
        let mut rng = Pcg64::new(21);
        let flat = layout.init_flat(&mut rng);
        let q = quantize_ppo(&layout, &flat, &shape);

        let b = 9;
        let obs = rand_mat(&mut rng, b, 5);
        let noise = rand_mat(&mut rng, b, 3);
        let fref = mlp::act(&layout, &flat, &shape, &obs, &noise);
        let got = q.forward_stochastic(&obs.data, &noise.data);

        for (g, e) in got.mean.iter().zip(&fref.mean.data) {
            assert!((g - e).abs() < 0.05, "mean {g} vs {e}");
        }
        for (g, e) in got.action.iter().zip(&fref.action.data) {
            assert!((g - e).abs() < 0.05, "action {g} vs {e}");
        }
        for (g, e) in got.value.iter().zip(&fref.value) {
            assert!((g - e).abs() < 0.1, "value {g} vs {e}");
        }
        for (g, e) in got.logp.iter().zip(&fref.logp) {
            assert!((g - e).abs() < 0.25, "logp {g} vs {e}");
        }
        assert!(got.action.iter().all(|v| v.is_finite()));
        assert!(got.logp.iter().all(|v| v.is_finite()));
    }

    /// int8 deterministic actor stays tanh-bounded and near the oracle.
    #[test]
    fn quantized_det_actor_tracks_f32_forward() {
        let shape = NetShape::new(4, 2, &[24, 24]);
        let layout = actor_layout(4, 2, &[24, 24]);
        let mut rng = Pcg64::new(22);
        let flat = layout.init_flat(&mut rng);
        let q = quantize_det_actor(&layout, &flat, &shape);

        let b = 7;
        let obs = rand_mat(&mut rng, b, 4);
        let fref = mlp::ddpg_actor(&layout, &flat, &shape, &obs);
        let got = q.forward_deterministic(&obs.data);
        assert_eq!(got.len(), b * 2);
        assert!(got.iter().all(|v| v.abs() <= 1.0));
        for (g, e) in got.iter().zip(&fref.data) {
            assert!((g - e).abs() < 0.05, "{g} vs {e}");
        }
    }

    /// Quantized forwards are deterministic (same input -> same bits) —
    /// the property the cross-shard flip machinery relies on.
    #[test]
    fn quantized_forward_is_deterministic() {
        let shape = NetShape::new(3, 2, &[16]);
        let layout = ppo_layout(3, 2, &[16]);
        let mut rng = Pcg64::new(23);
        let flat = layout.init_flat(&mut rng);
        let q = quantize_ppo(&layout, &flat, &shape);
        let obs = rand_mat(&mut rng, 4, 3);
        let noise = rand_mat(&mut rng, 4, 2);
        let a = q.forward_stochastic(&obs.data, &noise.data);
        let b = q.forward_stochastic(&obs.data, &noise.data);
        for (x, y) in a.action.iter().zip(&b.action) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.logp.iter().zip(&b.logp) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// Zero observations (zero dynamic range rows) must not NaN.
    #[test]
    fn zero_obs_rows_are_finite() {
        let shape = NetShape::new(3, 2, &[8]);
        let layout = ppo_layout(3, 2, &[8]);
        let mut rng = Pcg64::new(24);
        let flat = layout.init_flat(&mut rng);
        let q = quantize_ppo(&layout, &flat, &shape);
        let obs = vec![0.0f32; 2 * 3];
        let noise = vec![0.5f32; 2 * 2];
        let out = q.forward_stochastic(&obs, &noise);
        assert!(out.action.iter().all(|v| v.is_finite()));
        assert!(out.logp.iter().all(|v| v.is_finite()));
        assert!(out.value.iter().all(|v| v.is_finite()));
    }
}
