//! Native (pure-Rust) policy/value networks with manual backprop.
//!
//! This is the exact mathematical mirror of `python/compile/model.py` over
//! the same flat-parameter layout (`nn::layout`): tanh MLP Gaussian policy
//! with state-independent log-std, tanh MLP value function, PPO
//! clipped-surrogate loss, and the DDPG actor/critic. It serves three
//! roles: (1) the artifact-free `NativeBackend` so `cargo test` and quick
//! experiments run without Python, (2) an independent oracle the XLA
//! backend is integration-tested against, (3) the baseline for perf
//! comparisons in the benches.

use crate::nn::kernels;
use crate::nn::layout::ParamLayout;
use crate::nn::tensor::{
    act_grad_from_out, apply_act, col_sums, matmul_tn, mul_inplace, Act, Mat,
};

pub const LOG_2PI: f32 = 1.837877066409345;

/// Network hyper-shape (which layers exist inside the flat vector).
#[derive(Debug, Clone)]
pub struct NetShape {
    pub obs_dim: usize,
    pub act_dim: usize,
    pub hidden: Vec<usize>,
}

impl NetShape {
    pub fn new(obs_dim: usize, act_dim: usize, hidden: &[usize]) -> Self {
        Self {
            obs_dim,
            act_dim,
            hidden: hidden.to_vec(),
        }
    }
}

fn entry<'a>(layout: &ParamLayout, flat: &'a [f32], name: &str) -> (&'a [f32], Vec<usize>) {
    let e = layout
        .find(name)
        .unwrap_or_else(|| panic!("missing param {name}"));
    (&flat[e.offset..e.offset + e.size()], e.shape.clone())
}

/// Forward through an MLP prefix; returns every layer *output* (post-
/// activation), input first — the residuals manual backprop needs.
/// Weights are borrowed straight from the flat vector into the kernel
/// GEMM (no per-forward copies — this is the inference hot path).
fn mlp_forward(
    layout: &ParamLayout,
    flat: &[f32],
    prefix: &str,
    x: &Mat,
    n_hidden: usize,
    hidden_act: Act,
    out_act: Act,
) -> Vec<Mat> {
    let mut acts = vec![x.clone()];
    for i in 0..=n_hidden {
        let name = if i < n_hidden {
            format!("{prefix}/l{i}")
        } else {
            format!("{prefix}/out")
        };
        let (w, wshape) = entry(layout, flat, &format!("{name}/w"));
        let (b, _) = entry(layout, flat, &format!("{name}/b"));
        let xin = acts.last().unwrap();
        assert_eq!(xin.cols, wshape[0], "matmul dim mismatch");
        let mut y = Mat::zeros(xin.rows, wshape[1]);
        kernels::matmul(&xin.data, w, &mut y.data, xin.rows, wshape[0], wshape[1]);
        kernels::add_bias(&mut y.data, b, y.rows, y.cols);
        apply_act(&mut y, if i < n_hidden { hidden_act } else { out_act });
        acts.push(y);
    }
    acts
}

/// Backprop through an MLP prefix given the forward residuals. Writes
/// dW/db into `grad` (accumulating) and returns d(input).
fn mlp_backward(
    layout: &ParamLayout,
    flat: &[f32],
    prefix: &str,
    acts: &[Mat],
    mut dy: Mat,
    n_hidden: usize,
    hidden_act: Act,
    out_act: Act,
    grad: &mut [f32],
) -> Mat {
    for i in (0..=n_hidden).rev() {
        let name = if i < n_hidden {
            format!("{prefix}/l{i}")
        } else {
            format!("{prefix}/out")
        };
        let y = &acts[i + 1];
        let x = &acts[i];
        let g = act_grad_from_out(y, if i < n_hidden { hidden_act } else { out_act });
        mul_inplace(&mut dy, &g); // dz = dy * act'(y)
        let dw = matmul_tn(x, &dy); // x^T @ dz
        let db = col_sums(&dy);
        let we = layout.find(&format!("{name}/w")).unwrap();
        let be = layout.find(&format!("{name}/b")).unwrap();
        for (o, v) in grad[we.offset..we.offset + we.size()]
            .iter_mut()
            .zip(&dw.data)
        {
            *o += v;
        }
        for (o, v) in grad[be.offset..be.offset + be.size()].iter_mut().zip(&db) {
            *o += v;
        }
        // propagate to the layer input (at i == 0 this is d(network input),
        // which DDPG's actor update needs as dQ/da)
        let (w, wshape) = entry(layout, flat, &format!("{name}/w"));
        let mut dx = Mat::zeros(dy.rows, wshape[0]);
        // dz @ w^T: w is [k_in, n_out] row-major = the b^T operand as-is
        kernels::matmul_nt(&dy.data, w, &mut dx.data, dy.rows, wshape[1], wshape[0]);
        dy = dx;
    }
    dy
}

// ---------------------------------------------------------------------------
// PPO policy/value
// ---------------------------------------------------------------------------

/// Output of one batched `act` call (mirrors the AOT `act` artifact).
#[derive(Debug, Clone)]
pub struct ActOut {
    pub action: Mat,
    pub logp: Vec<f32>,
    pub value: Vec<f32>,
    pub mean: Mat,
}

/// mean[B,A], log_std[A], value[B] for a batch of observations.
pub fn policy_value(
    layout: &ParamLayout,
    flat: &[f32],
    shape: &NetShape,
    obs: &Mat,
) -> (Mat, Vec<f32>, Vec<f32>) {
    let nh = shape.hidden.len();
    let pi = mlp_forward(layout, flat, "pi", obs, nh, Act::Tanh, Act::Id);
    let vf = mlp_forward(layout, flat, "vf", obs, nh, Act::Tanh, Act::Id);
    let mean = pi.last().unwrap().clone();
    let value = vf.last().unwrap().data.clone();
    let (log_std, _) = entry(layout, flat, "pi/log_std");
    (mean, log_std.to_vec(), value)
}

/// Diagonal-Gaussian log-density summed over actions. The per-dim
/// `exp(-log_std)` and the constant term are hoisted out of the row loop
/// (they were recomputed B*A times — a measurable slice of the act hot
/// path); the row reduction itself stays sequential (exact-mode order).
pub fn gaussian_logp(a: &Mat, mean: &Mat, log_std: &[f32]) -> Vec<f32> {
    let inv_std: Vec<f32> = log_std.iter().map(|ls| (-ls).exp()).collect();
    let base: f32 = log_std.iter().map(|ls| -ls - 0.5 * LOG_2PI).sum();
    let mut out = vec![0.0; a.rows];
    for r in 0..a.rows {
        let arow = a.row(r);
        let mrow = mean.row(r);
        let mut acc = 0.0f32;
        for c in 0..a.cols {
            let z = (arow[c] - mrow[c]) * inv_std[c];
            acc += -0.5 * z * z;
        }
        out[r] = acc + base;
    }
    out
}

/// Entropy of the (state-independent) Gaussian.
pub fn gaussian_entropy(log_std: &[f32]) -> f32 {
    log_std.iter().map(|ls| ls + 0.5 * (LOG_2PI + 1.0)).sum()
}

/// Sampler entry point: action = mean + exp(log_std) * noise.
pub fn act(
    layout: &ParamLayout,
    flat: &[f32],
    shape: &NetShape,
    obs: &Mat,
    noise: &Mat,
) -> ActOut {
    let (mean, log_std, value) = policy_value(layout, flat, shape, obs);
    let std: Vec<f32> = log_std.iter().map(|ls| ls.exp()).collect();
    let mut action = mean.clone();
    for r in 0..action.rows {
        let arow = action.row_mut(r);
        let nrow = noise.row(r);
        for c in 0..arow.len() {
            arow[c] += std[c] * nrow[c];
        }
    }
    let logp = gaussian_logp(&action, &mean, &log_std);
    ActOut {
        action,
        logp,
        value,
        mean,
    }
}

/// PPO hyper-parameters baked into the loss (mirror of model.PpoConfig).
#[derive(Debug, Clone, Copy)]
pub struct PpoLossCfg {
    pub clip: f32,
    pub ent_coef: f32,
    pub vf_coef: f32,
}

impl Default for PpoLossCfg {
    fn default() -> Self {
        Self {
            clip: 0.2,
            ent_coef: 0.0,
            vf_coef: 0.5,
        }
    }
}

/// One PPO minibatch (rows already padded/masked by the caller).
#[derive(Debug, Clone)]
pub struct PpoBatch {
    pub obs: Mat,
    pub act: Mat,
    pub old_logp: Vec<f32>,
    pub adv: Vec<f32>,
    pub ret: Vec<f32>,
    pub mask: Vec<f32>,
}

/// Loss statistics (mirror of the AOT train_ppo tuple tail).
#[derive(Debug, Clone, Copy, Default)]
pub struct PpoStats {
    pub total: f32,
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub clip_frac: f32,
}

/// PPO clipped-surrogate loss and its gradient w.r.t. the flat vector.
/// Exact mirror of `model.ppo_loss` (masked means, same clip semantics).
pub fn ppo_loss_grad(
    layout: &ParamLayout,
    flat: &[f32],
    shape: &NetShape,
    batch: &PpoBatch,
    cfg: &PpoLossCfg,
) -> (Vec<f32>, PpoStats) {
    let nh = shape.hidden.len();
    let b = batch.obs.rows;
    assert_eq!(batch.act.rows, b);

    let pi_acts = mlp_forward(layout, flat, "pi", &batch.obs, nh, Act::Tanh, Act::Id);
    let vf_acts = mlp_forward(layout, flat, "vf", &batch.obs, nh, Act::Tanh, Act::Id);
    let mean = pi_acts.last().unwrap();
    let value = &vf_acts.last().unwrap().data;
    let (log_std, _) = entry(layout, flat, "pi/log_std");

    let logp = gaussian_logp(&batch.act, mean, log_std);
    let w: f32 = batch.mask.iter().sum::<f32>().max(1.0);

    // --- forward losses + per-row dlogp coefficient
    let mut pi_loss = 0.0f32;
    let mut v_loss = 0.0f32;
    let mut approx_kl = 0.0f32;
    let mut clip_frac = 0.0f32;
    let mut dlogp = vec![0.0f32; b]; // dL/dlogp_i
    let mut dvalue = vec![0.0f32; b]; // dL/dvalue_i
    for i in 0..b {
        let m = batch.mask[i];
        if m == 0.0 {
            continue;
        }
        let ratio = (logp[i] - batch.old_logp[i]).exp();
        let clipped = ratio.clamp(1.0 - cfg.clip, 1.0 + cfg.clip);
        let s1 = ratio * batch.adv[i];
        let s2 = clipped * batch.adv[i];
        let surr = s1.min(s2);
        pi_loss -= m * surr / w;
        // gradient flows only through the unclipped branch when it is the min
        if s1 <= s2 {
            dlogp[i] = -m * batch.adv[i] * ratio / w;
        }
        let verr = value[i] - batch.ret[i];
        v_loss += 0.5 * m * verr * verr / w;
        dvalue[i] = cfg.vf_coef * m * verr / w;
        approx_kl += m * (batch.old_logp[i] - logp[i]) / w;
        if (ratio - 1.0).abs() > cfg.clip {
            clip_frac += m / w;
        }
    }
    let entropy = gaussian_entropy(log_std);
    let total = pi_loss + cfg.vf_coef * v_loss - cfg.ent_coef * entropy;

    // --- backward
    let mut grad = vec![0.0f32; layout.total()];

    // dlogp -> dmean and dlog_std
    let a = shape.act_dim;
    let mut dmean = Mat::zeros(b, a);
    let ls_e = layout.find("pi/log_std").unwrap();
    let inv_stds: Vec<f32> = log_std.iter().map(|ls| (-ls).exp()).collect();
    for i in 0..b {
        if dlogp[i] == 0.0 && batch.mask[i] == 0.0 {
            continue;
        }
        for j in 0..a {
            let inv_std = inv_stds[j];
            let z = (batch.act.at(i, j) - mean.at(i, j)) * inv_std;
            // dlogp/dmean_j = z * inv_std ; dlogp/dlog_std_j = z^2 - 1
            *dmean.at_mut(i, j) = dlogp[i] * z * inv_std;
            grad[ls_e.offset + j] += dlogp[i] * (z * z - 1.0);
        }
    }
    // entropy: dL/dlog_std_j -= ent_coef
    for j in 0..a {
        grad[ls_e.offset + j] -= cfg.ent_coef;
    }

    mlp_backward(
        layout, flat, "pi", &pi_acts, dmean, nh, Act::Tanh, Act::Id, &mut grad,
    );
    let dv = Mat::from_vec(b, 1, dvalue);
    mlp_backward(
        layout, flat, "vf", &vf_acts, dv, nh, Act::Tanh, Act::Id, &mut grad,
    );

    (
        grad,
        PpoStats {
            total,
            pi_loss,
            v_loss,
            entropy,
            approx_kl,
            clip_frac,
        },
    )
}

// ---------------------------------------------------------------------------
// DDPG actor/critic
// ---------------------------------------------------------------------------

/// Deterministic actor forward: relu hidden, tanh output.
pub fn ddpg_actor(
    layout: &ParamLayout,
    flat: &[f32],
    shape: &NetShape,
    obs: &Mat,
) -> Mat {
    mlp_forward(layout, flat, "actor", obs, shape.hidden.len(), Act::Relu, Act::Tanh)
        .pop()
        .unwrap()
}

/// Critic forward on concat(obs, act).
pub fn ddpg_critic(
    layout: &ParamLayout,
    flat: &[f32],
    shape: &NetShape,
    obs: &Mat,
    action: &Mat,
) -> Vec<f32> {
    let x = concat_cols(obs, action);
    mlp_forward(layout, flat, "critic", &x, shape.hidden.len(), Act::Relu, Act::Id)
        .pop()
        .unwrap()
        .data
}

pub fn concat_cols(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows);
    let mut out = Mat::zeros(a.rows, a.cols + b.cols);
    for r in 0..a.rows {
        out.row_mut(r)[..a.cols].copy_from_slice(a.row(r));
        out.row_mut(r)[a.cols..].copy_from_slice(b.row(r));
    }
    out
}

/// Gradient of mean squared TD error w.r.t. critic params.
/// Returns (grad, q_loss).
pub fn ddpg_critic_grad(
    layout: &ParamLayout,
    flat: &[f32],
    shape: &NetShape,
    obs: &Mat,
    action: &Mat,
    target: &[f32],
) -> (Vec<f32>, f32) {
    let nh = shape.hidden.len();
    let x = concat_cols(obs, action);
    let acts = mlp_forward(layout, flat, "critic", &x, nh, Act::Relu, Act::Id);
    let q = &acts.last().unwrap().data;
    let b = q.len() as f32;
    let mut loss = 0.0;
    let mut dq = Mat::zeros(q.len(), 1);
    for i in 0..q.len() {
        let e = q[i] - target[i];
        loss += e * e / b;
        dq.data[i] = 2.0 * e / b;
    }
    let mut grad = vec![0.0f32; layout.total()];
    mlp_backward(layout, flat, "critic", &acts, dq, nh, Act::Relu, Act::Id, &mut grad);
    (grad, loss)
}

/// Gradient of -mean(Q(s, actor(s))) w.r.t. actor params (DPG step).
/// Returns (actor_grad, pi_loss).
pub fn ddpg_actor_grad(
    alayout: &ParamLayout,
    actor_flat: &[f32],
    clayout: &ParamLayout,
    critic_flat: &[f32],
    shape: &NetShape,
    obs: &Mat,
) -> (Vec<f32>, f32) {
    let nh = shape.hidden.len();
    let acts = mlp_forward(alayout, actor_flat, "actor", obs, nh, Act::Relu, Act::Tanh);
    let action = acts.last().unwrap().clone();
    let x = concat_cols(obs, &action);
    let cacts = mlp_forward(clayout, critic_flat, "critic", &x, nh, Act::Relu, Act::Id);
    let q = &cacts.last().unwrap().data;
    let b = q.len() as f32;
    let pi_loss = -q.iter().sum::<f32>() / b;

    // dL/dq = -1/B; backprop through critic to its *input*, slice action part
    let dq = Mat::from_vec(q.len(), 1, vec![-1.0 / b; q.len()]);
    let mut scratch = vec![0.0f32; clayout.total()]; // critic grads discarded
    let dx = mlp_backward(
        clayout, critic_flat, "critic", &cacts, dq, nh, Act::Relu, Act::Id, &mut scratch,
    );
    let mut da = Mat::zeros(obs.rows, shape.act_dim);
    for r in 0..obs.rows {
        da.row_mut(r)
            .copy_from_slice(&dx.row(r)[shape.obs_dim..]);
    }

    let mut grad = vec![0.0f32; alayout.total()];
    mlp_backward(
        alayout, actor_flat, "actor", &acts, da, nh, Act::Relu, Act::Tanh, &mut grad,
    );
    (grad, pi_loss)
}

/// Per-grain critic gradient for the deterministic parallel learner:
/// squared TD error with optional importance weights, scaled by a
/// caller-supplied `inv_n` (1 / full-batch size — NOT 1 / grain size, so
/// grain partials sum to the full-batch gradient under `tree_reduce`).
/// Returns `(grad, loss_part, residuals)`; `residuals[i] = q_i - target_i`
/// feeds prioritized-replay updates.
pub fn ddpg_critic_grad_weighted(
    layout: &ParamLayout,
    flat: &[f32],
    shape: &NetShape,
    obs: &Mat,
    action: &Mat,
    target: &[f32],
    weights: Option<&[f32]>,
    inv_n: f32,
) -> (Vec<f32>, f32, Vec<f32>) {
    let nh = shape.hidden.len();
    let x = concat_cols(obs, action);
    let acts = mlp_forward(layout, flat, "critic", &x, nh, Act::Relu, Act::Id);
    let q = &acts.last().unwrap().data;
    let mut loss = 0.0;
    let mut dq = Mat::zeros(q.len(), 1);
    let mut residuals = vec![0.0f32; q.len()];
    for i in 0..q.len() {
        let w = weights.map_or(1.0, |ws| ws[i]);
        let e = q[i] - target[i];
        residuals[i] = e;
        loss += w * e * e * inv_n;
        dq.data[i] = 2.0 * w * e * inv_n;
    }
    let mut grad = vec![0.0f32; layout.total()];
    mlp_backward(layout, flat, "critic", &acts, dq, nh, Act::Relu, Act::Id, &mut grad);
    (grad, loss, residuals)
}

/// Per-grain DPG actor gradient: like [`ddpg_actor_grad`] but scaled by a
/// caller-supplied `inv_n` instead of `1 / grain rows`, so grain partials
/// tree-reduce to the full-batch gradient.
pub fn ddpg_actor_grad_scaled(
    alayout: &ParamLayout,
    actor_flat: &[f32],
    clayout: &ParamLayout,
    critic_flat: &[f32],
    shape: &NetShape,
    obs: &Mat,
    inv_n: f32,
) -> (Vec<f32>, f32) {
    let nh = shape.hidden.len();
    let acts = mlp_forward(alayout, actor_flat, "actor", obs, nh, Act::Relu, Act::Tanh);
    let action = acts.last().unwrap().clone();
    let x = concat_cols(obs, &action);
    let cacts = mlp_forward(clayout, critic_flat, "critic", &x, nh, Act::Relu, Act::Id);
    let q = &cacts.last().unwrap().data;
    let pi_loss = -q.iter().sum::<f32>() * inv_n;

    let dq = Mat::from_vec(q.len(), 1, vec![-inv_n; q.len()]);
    let mut scratch = vec![0.0f32; clayout.total()]; // critic grads discarded
    let dx = mlp_backward(
        clayout, critic_flat, "critic", &cacts, dq, nh, Act::Relu, Act::Id, &mut scratch,
    );
    let mut da = Mat::zeros(obs.rows, shape.act_dim);
    for r in 0..obs.rows {
        da.row_mut(r)
            .copy_from_slice(&dx.row(r)[shape.obs_dim..]);
    }
    let mut grad = vec![0.0f32; alayout.total()];
    mlp_backward(
        alayout, actor_flat, "actor", &acts, da, nh, Act::Relu, Act::Tanh, &mut grad,
    );
    (grad, pi_loss)
}

// ---------------------------------------------------------------------------
// SAC reparameterized tanh-Gaussian actor
// ---------------------------------------------------------------------------

/// SAC log-std head clamp bounds (standard soft actor-critic values).
pub const SAC_LOG_STD_MIN: f32 = -20.0;
pub const SAC_LOG_STD_MAX: f32 = 2.0;

/// Numerically stable `ln(1 + e^x)`.
fn softplus(x: f32) -> f32 {
    if x > 0.0 {
        x + (-x).exp().ln_1p()
    } else {
        x.exp().ln_1p()
    }
}

/// Output of one batched SAC `act`: squashed sample, its tanh-corrected
/// log-density, and the deterministic (mean) action for evaluation.
#[derive(Debug, Clone)]
pub struct SacActOut {
    pub action: Mat,
    pub logp: Vec<f32>,
    pub mean_action: Mat,
}

/// SAC actor forward. The head (relu hidden, identity out, width
/// `2 * act_dim` over `actor_layout(obs_dim, 2 * act_dim, hidden)`) splits
/// into per-dim mean and clamped log-std; the reparameterized sample is
/// `a = tanh(mean + exp(log_std) * eps)` with
/// `log pi(a) = sum_j [-0.5 eps_j^2 - log_std_j - 0.5 LOG_2PI
///                     - log(1 - tanh^2 u_j)]`,
/// using the stable identity
/// `log(1 - tanh^2 u) = 2 (ln 2 - u - softplus(-2u))`. Zero (or empty)
/// `eps` yields the mode `tanh(mean)` — the evaluation path.
pub fn sac_act(
    layout: &ParamLayout,
    flat: &[f32],
    shape: &NetShape,
    obs: &Mat,
    eps: &[f32],
) -> SacActOut {
    let nh = shape.hidden.len();
    let acts = mlp_forward(layout, flat, "actor", obs, nh, Act::Relu, Act::Id);
    let head = acts.last().unwrap();
    let a_dim = shape.act_dim;
    debug_assert_eq!(head.cols, 2 * a_dim, "SAC head must be mean ++ log_std");
    let rows = head.rows;
    let mut action = Mat::zeros(rows, a_dim);
    let mut mean_action = Mat::zeros(rows, a_dim);
    let mut logp = vec![0.0f32; rows];
    for r in 0..rows {
        let h = head.row(r);
        let mut lp = 0.0f32;
        for j in 0..a_dim {
            let mean = h[j];
            let ls = h[a_dim + j].clamp(SAC_LOG_STD_MIN, SAC_LOG_STD_MAX);
            let e = if eps.is_empty() { 0.0 } else { eps[r * a_dim + j] };
            let u = mean + ls.exp() * e;
            lp += -0.5 * e * e - ls - 0.5 * LOG_2PI
                - 2.0 * (std::f32::consts::LN_2 - u - softplus(-2.0 * u));
            *action.at_mut(r, j) = u.tanh();
            *mean_action.at_mut(r, j) = mean.tanh();
        }
        logp[r] = lp;
    }
    SacActOut {
        action,
        logp,
        mean_action,
    }
}

/// Gradient of the SAC policy objective
/// `inv_n * sum_i [alpha * log pi(a_i|s_i) - min(Q1(s_i,a_i), Q2(s_i,a_i))]`
/// w.r.t. the actor parameters, with `a_i` reparameterized through `eps`.
/// Returns `(actor_grad, pi_loss, logp_sum)`; `logp_sum` (un-scaled) feeds
/// the temperature update. Clamped log-std dims get zero gradient.
pub fn sac_actor_grad(
    alayout: &ParamLayout,
    actor_flat: &[f32],
    clayout: &ParamLayout,
    c1_flat: &[f32],
    c2_flat: &[f32],
    shape: &NetShape,
    obs: &Mat,
    eps: &[f32],
    alpha: f32,
    inv_n: f32,
) -> (Vec<f32>, f32, f32) {
    let nh = shape.hidden.len();
    let a_dim = shape.act_dim;
    let rows = obs.rows;
    let acts = mlp_forward(alayout, actor_flat, "actor", obs, nh, Act::Relu, Act::Id);
    let head = acts.last().unwrap();
    debug_assert_eq!(head.cols, 2 * a_dim);

    let mut action = Mat::zeros(rows, a_dim);
    let mut stds = Mat::zeros(rows, a_dim);
    let mut clamped = vec![false; rows * a_dim];
    let mut logp = vec![0.0f32; rows];
    for r in 0..rows {
        let h = head.row(r);
        let mut lp = 0.0f32;
        for j in 0..a_dim {
            let raw = h[a_dim + j];
            let ls = raw.clamp(SAC_LOG_STD_MIN, SAC_LOG_STD_MAX);
            let k = r * a_dim + j;
            clamped[k] = raw != ls;
            let e = if eps.is_empty() { 0.0 } else { eps[k] };
            let std = ls.exp();
            let u = h[j] + std * e;
            lp += -0.5 * e * e - ls - 0.5 * LOG_2PI
                - 2.0 * (std::f32::consts::LN_2 - u - softplus(-2.0 * u));
            *action.at_mut(r, j) = u.tanh();
            *stds.at_mut(r, j) = std;
        }
        logp[r] = lp;
    }

    let x = concat_cols(obs, &action);
    let c1acts = mlp_forward(clayout, c1_flat, "critic", &x, nh, Act::Relu, Act::Id);
    let c2acts = mlp_forward(clayout, c2_flat, "critic", &x, nh, Act::Relu, Act::Id);
    let q1 = &c1acts.last().unwrap().data;
    let q2 = &c2acts.last().unwrap().data;

    let mut loss = 0.0f32;
    let mut logp_sum = 0.0f32;
    let mut dq1 = Mat::zeros(rows, 1);
    let mut dq2 = Mat::zeros(rows, 1);
    for r in 0..rows {
        loss += inv_n * (alpha * logp[r] - q1[r].min(q2[r]));
        logp_sum += logp[r];
        // gradient flows through whichever critic attains the min
        if q1[r] <= q2[r] {
            dq1.data[r] = -inv_n;
        } else {
            dq2.data[r] = -inv_n;
        }
    }
    let mut scratch1 = vec![0.0f32; clayout.total()]; // critic grads discarded
    let dx1 = mlp_backward(
        clayout, c1_flat, "critic", &c1acts, dq1, nh, Act::Relu, Act::Id, &mut scratch1,
    );
    let mut scratch2 = vec![0.0f32; clayout.total()];
    let dx2 = mlp_backward(
        clayout, c2_flat, "critic", &c2acts, dq2, nh, Act::Relu, Act::Id, &mut scratch2,
    );

    // chain back to the head: d/du = dQ-route * (1 - a^2) + entropy-route
    // (d log pi / du = 2a); mean lane gets du, log-std lane gets
    // du * std * eps (through u) minus the direct -alpha/N term.
    let mut dhead = Mat::zeros(rows, 2 * a_dim);
    for r in 0..rows {
        for j in 0..a_dim {
            let a = action.at(r, j);
            let da = dx1.at(r, shape.obs_dim + j) + dx2.at(r, shape.obs_dim + j);
            let du = da * (1.0 - a * a) + inv_n * alpha * 2.0 * a;
            *dhead.at_mut(r, j) = du;
            let k = r * a_dim + j;
            if !clamped[k] {
                let e = if eps.is_empty() { 0.0 } else { eps[k] };
                *dhead.at_mut(r, a_dim + j) = du * stds.at(r, j) * e - inv_n * alpha;
            }
        }
    }
    let mut grad = vec![0.0f32; alayout.total()];
    mlp_backward(
        alayout, actor_flat, "actor", &acts, dhead, nh, Act::Relu, Act::Id, &mut grad,
    );
    (grad, loss, logp_sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layout::{actor_layout, critic_layout, ppo_layout};
    use crate::util::rng::Pcg64;

    fn setup() -> (ParamLayout, Vec<f32>, NetShape) {
        let shape = NetShape::new(3, 2, &[16, 16]);
        let layout = ppo_layout(3, 2, &[16, 16]);
        let mut rng = Pcg64::new(0);
        let flat = layout.init_flat(&mut rng);
        (layout, flat, shape)
    }

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_normal(&mut m.data);
        m
    }

    #[test]
    fn act_zero_noise_returns_mean() {
        let (layout, flat, shape) = setup();
        let mut rng = Pcg64::new(1);
        let obs = rand_mat(&mut rng, 5, 3);
        let noise = Mat::zeros(5, 2);
        let out = act(&layout, &flat, &shape, &obs, &noise);
        assert!(out.action.max_abs_diff(&out.mean) < 1e-7);
        assert_eq!(out.logp.len(), 5);
        assert_eq!(out.value.len(), 5);
    }

    #[test]
    fn logp_matches_closed_form() {
        let mean = Mat::from_rows(&[&[0.5, -1.0]]);
        let a = Mat::from_rows(&[&[0.7, -0.5]]);
        let log_std = [0.1f32, -0.3];
        let got = gaussian_logp(&a, &mean, &log_std)[0];
        let mut want = 0.0f32;
        for i in 0..2 {
            let s = log_std[i].exp();
            let z = (a.at(0, i) - mean.at(0, i)) / s;
            want += -0.5 * z * z - log_std[i] - 0.5 * LOG_2PI;
        }
        assert!((got - want).abs() < 1e-6);
    }

    /// Finite-difference check of the full PPO gradient — the strongest
    /// native-side correctness signal.
    #[test]
    fn ppo_grad_matches_finite_difference() {
        let (layout, flat, shape) = setup();
        let mut rng = Pcg64::new(2);
        let b = 8;
        let obs = rand_mat(&mut rng, b, 3);
        let noise = rand_mat(&mut rng, b, 2);
        let out = act(&layout, &flat, &shape, &obs, &noise);
        // perturbed old_logp so ratios differ from 1 (exercise clip paths)
        let old_logp: Vec<f32> = out.logp.iter().map(|l| l - 0.2).collect();
        let adv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let ret: Vec<f32> = out.value.iter().map(|v| v + 0.3).collect();
        let batch = PpoBatch {
            obs,
            act: out.action.clone(),
            old_logp,
            adv,
            ret,
            mask: vec![1.0; b],
        };
        let cfg = PpoLossCfg {
            clip: 0.2,
            ent_coef: 0.01,
            vf_coef: 0.5,
        };
        let (grad, stats) = ppo_loss_grad(&layout, &flat, &shape, &batch, &cfg);

        let loss_of = |f: &[f32]| ppo_loss_grad(&layout, f, &shape, &batch, &cfg).1.total;
        let eps = 3e-3f32;
        let mut checked = 0;
        // probe a spread of parameter indices incl. log_std
        let ls_off = layout.find("pi/log_std").unwrap().offset;
        let mut idxs: Vec<usize> = (0..layout.total()).step_by(layout.total() / 40).collect();
        idxs.push(ls_off);
        idxs.push(ls_off + 1);
        for &i in &idxs {
            let mut fp = flat.clone();
            fp[i] += eps;
            let mut fm = flat.clone();
            fm[i] -= eps;
            let fd = (loss_of(&fp) - loss_of(&fm)) / (2.0 * eps);
            let denom = fd.abs().max(grad[i].abs()).max(1e-2);
            assert!(
                (fd - grad[i]).abs() / denom < 0.08,
                "param {i}: fd={fd} analytic={}",
                grad[i]
            );
            checked += 1;
        }
        assert!(checked > 30);
        assert!(stats.total.is_finite());
    }

    #[test]
    fn ppo_mask_zeroes_padding_contribution() {
        let (layout, flat, shape) = setup();
        let mut rng = Pcg64::new(3);
        let obs = rand_mat(&mut rng, 6, 3);
        let noise = rand_mat(&mut rng, 6, 2);
        let out = act(&layout, &flat, &shape, &obs, &noise);
        let mk = |mask: Vec<f32>, adv_tail: f32| PpoBatch {
            obs: obs.clone(),
            act: out.action.clone(),
            old_logp: out.logp.clone(),
            adv: vec![0.5, -0.2, 0.1, adv_tail, adv_tail, adv_tail],
            ret: out.value.clone(),
            mask,
        };
        let cfg = PpoLossCfg::default();
        let full = mk(vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0], 1e6);
        let (g1, s1) = ppo_loss_grad(&layout, &flat, &shape, &full, &cfg);
        let clean = mk(vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0], 0.0);
        let (g2, s2) = ppo_loss_grad(&layout, &flat, &shape, &clean, &cfg);
        assert!((s1.total - s2.total).abs() < 1e-5);
        let diff: f32 = g1
            .iter()
            .zip(&g2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-5);
    }

    #[test]
    fn ratio_one_gives_zero_kl_and_clipfrac() {
        let (layout, flat, shape) = setup();
        let mut rng = Pcg64::new(4);
        let obs = rand_mat(&mut rng, 4, 3);
        let noise = rand_mat(&mut rng, 4, 2);
        let out = act(&layout, &flat, &shape, &obs, &noise);
        let batch = PpoBatch {
            obs,
            act: out.action,
            old_logp: out.logp,
            adv: vec![1.0; 4],
            ret: out.value,
            mask: vec![1.0; 4],
        };
        let (_, stats) = ppo_loss_grad(&layout, &flat, &shape, &batch, &PpoLossCfg::default());
        assert!(stats.approx_kl.abs() < 1e-5);
        assert_eq!(stats.clip_frac, 0.0);
        assert!((stats.pi_loss + 1.0).abs() < 1e-5); // -mean(adv) = -1
    }

    #[test]
    fn ddpg_actor_bounded_and_critic_grad_fd() {
        let shape = NetShape::new(3, 2, &[8, 8]);
        let al = actor_layout(3, 2, &[8, 8]);
        let cl = critic_layout(3, 2, &[8, 8]);
        let mut rng = Pcg64::new(5);
        let af = al.init_flat(&mut rng);
        let cf = cl.init_flat(&mut rng);
        let obs = rand_mat(&mut rng, 6, 3);
        let a = ddpg_actor(&al, &af, &shape, &obs);
        assert!(a.data.iter().all(|v| v.abs() <= 1.0));

        let target = vec![0.7f32; 6];
        let (grad, _q) = ddpg_critic_grad(&cl, &cf, &shape, &obs, &a, &target);
        let loss_of = |f: &[f32]| {
            let q = ddpg_critic(&cl, f, &shape, &obs, &a);
            q.iter()
                .zip(&target)
                .map(|(qi, ti)| (qi - ti) * (qi - ti))
                .sum::<f32>()
                / 6.0
        };
        let eps = 2e-3;
        for i in (0..cl.total()).step_by(cl.total() / 25) {
            let mut fp = cf.clone();
            fp[i] += eps;
            let mut fm = cf.clone();
            fm[i] -= eps;
            let fd = (loss_of(&fp) - loss_of(&fm)) / (2.0 * eps);
            let denom = fd.abs().max(grad[i].abs()).max(1e-2);
            assert!((fd - grad[i]).abs() / denom < 0.08, "param {i}");
        }
    }

    #[test]
    fn ddpg_actor_grad_fd() {
        let shape = NetShape::new(3, 2, &[8, 8]);
        let al = actor_layout(3, 2, &[8, 8]);
        let cl = critic_layout(3, 2, &[8, 8]);
        let mut rng = Pcg64::new(6);
        let af = al.init_flat(&mut rng);
        let cf = cl.init_flat(&mut rng);
        let obs = rand_mat(&mut rng, 5, 3);
        let (grad, _pi) = ddpg_actor_grad(&al, &af, &cl, &cf, &shape, &obs);
        let loss_of = |f: &[f32]| {
            let a = ddpg_actor(&al, f, &shape, &obs);
            -ddpg_critic(&cl, &cf, &shape, &obs, &a).iter().sum::<f32>() / 5.0
        };
        let eps = 2e-3;
        for i in (0..al.total()).step_by(al.total() / 25) {
            let mut fp = af.clone();
            fp[i] += eps;
            let mut fm = af.clone();
            fm[i] -= eps;
            let fd = (loss_of(&fp) - loss_of(&fm)) / (2.0 * eps);
            let denom = fd.abs().max(grad[i].abs()).max(1e-2);
            assert!((fd - grad[i]).abs() / denom < 0.1, "param {i}");
        }
    }

    /// The grain-scaled variants must agree with the classic full-batch
    /// fns when `weights = 1` and `inv_n = 1/B` (up to fp association).
    #[test]
    fn scaled_grads_match_full_batch_forms() {
        let shape = NetShape::new(3, 2, &[8, 8]);
        let al = actor_layout(3, 2, &[8, 8]);
        let cl = critic_layout(3, 2, &[8, 8]);
        let mut rng = Pcg64::new(7);
        let af = al.init_flat(&mut rng);
        let cf = cl.init_flat(&mut rng);
        let obs = rand_mat(&mut rng, 6, 3);
        let act = ddpg_actor(&al, &af, &shape, &obs);
        let target = vec![0.3f32; 6];

        let (g0, l0) = ddpg_critic_grad(&cl, &cf, &shape, &obs, &act, &target);
        let (g1, l1, res) =
            ddpg_critic_grad_weighted(&cl, &cf, &shape, &obs, &act, &target, None, 1.0 / 6.0);
        assert!((l0 - l1).abs() < 1e-5);
        for (a, b) in g0.iter().zip(&g1) {
            assert!((a - b).abs() < 1e-5);
        }
        let q = ddpg_critic(&cl, &cf, &shape, &obs, &act);
        for (i, r) in res.iter().enumerate() {
            assert!((r - (q[i] - target[i])).abs() < 1e-6);
        }

        let (ag0, pl0) = ddpg_actor_grad(&al, &af, &cl, &cf, &shape, &obs);
        let (ag1, pl1) = ddpg_actor_grad_scaled(&al, &af, &cl, &cf, &shape, &obs, 1.0 / 6.0);
        assert!((pl0 - pl1).abs() < 1e-5);
        for (a, b) in ag0.iter().zip(&ag1) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    /// Importance weights scale each row's contribution linearly.
    #[test]
    fn weighted_critic_grad_scales_rows() {
        let shape = NetShape::new(3, 1, &[8]);
        let cl = critic_layout(3, 1, &[8]);
        let mut rng = Pcg64::new(8);
        let cf = cl.init_flat(&mut rng);
        let obs = rand_mat(&mut rng, 1, 3);
        let act = rand_mat(&mut rng, 1, 1);
        let target = vec![0.1f32];
        let (g1, l1, _) =
            ddpg_critic_grad_weighted(&cl, &cf, &shape, &obs, &act, &target, Some(&[1.0]), 1.0);
        let (g2, l2, _) =
            ddpg_critic_grad_weighted(&cl, &cf, &shape, &obs, &act, &target, Some(&[0.5]), 1.0);
        assert!((l1 - 2.0 * l2).abs() < 1e-5);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - 2.0 * b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn sac_act_zero_eps_is_mode_and_bounded() {
        let shape = NetShape::new(3, 2, &[8, 8]);
        let al = actor_layout(3, 2 * 2, &[8, 8]);
        let mut rng = Pcg64::new(9);
        let af = al.init_flat(&mut rng);
        let obs = rand_mat(&mut rng, 5, 3);
        let out = sac_act(&al, &af, &shape, &obs, &[]);
        assert!(out.action.max_abs_diff(&out.mean_action) < 1e-7);
        assert!(out.action.data.iter().all(|v| v.abs() <= 1.0));
        assert_eq!(out.logp.len(), 5);
        assert!(out.logp.iter().all(|l| l.is_finite()));
        // nonzero eps perturbs the sample but not the mode
        let mut eps = vec![0.0f32; 5 * 2];
        rng.fill_normal(&mut eps);
        let out2 = sac_act(&al, &af, &shape, &obs, &eps);
        assert!(out2.mean_action.max_abs_diff(&out.mean_action) < 1e-7);
        assert!(out2.action.max_abs_diff(&out.action) > 1e-4);
    }

    #[test]
    fn sac_logp_matches_closed_form_density() {
        // 1-D check against the change-of-variables formula evaluated
        // directly: log N(u) - log(1 - tanh^2 u), u = mean + std * eps.
        let shape = NetShape::new(2, 1, &[4]);
        let al = actor_layout(2, 2, &[4]);
        let mut rng = Pcg64::new(10);
        let af = al.init_flat(&mut rng);
        let obs = rand_mat(&mut rng, 1, 2);
        let eps = [0.7f32];
        let out = sac_act(&al, &af, &shape, &obs, &eps);
        // recover mean/log_std from the raw head
        let head = mlp_forward(&al, &af, "actor", &obs, 1, Act::Relu, Act::Id)
            .pop()
            .unwrap();
        let mean = head.at(0, 0);
        let ls = head.at(0, 1).clamp(SAC_LOG_STD_MIN, SAC_LOG_STD_MAX);
        let u = mean + ls.exp() * eps[0];
        let a = u.tanh();
        let want = -0.5 * eps[0] * eps[0] - ls - 0.5 * LOG_2PI - (1.0 - a * a).ln();
        assert!((out.logp[0] - want).abs() < 1e-4, "{} vs {want}", out.logp[0]);
        assert!((out.action.at(0, 0) - a).abs() < 1e-6);
    }

    /// Finite-difference check of the full SAC policy gradient (actor
    /// params through both critics, the tanh correction, and the
    /// reparameterized entropy term).
    #[test]
    fn sac_actor_grad_fd() {
        let shape = NetShape::new(3, 2, &[8, 8]);
        let al = actor_layout(3, 2 * 2, &[8, 8]);
        let cl = critic_layout(3, 2, &[8, 8]);
        let mut rng = Pcg64::new(11);
        let af = al.init_flat(&mut rng);
        let c1 = cl.init_flat(&mut rng);
        let c2 = cl.init_flat(&mut rng);
        let obs = rand_mat(&mut rng, 5, 3);
        let mut eps = vec![0.0f32; 5 * 2];
        rng.fill_normal(&mut eps);
        let alpha = 0.2f32;
        let inv_n = 1.0 / 5.0;
        let (grad, loss, logp_sum) =
            sac_actor_grad(&al, &af, &cl, &c1, &c2, &shape, &obs, &eps, alpha, inv_n);
        let loss_of = |f: &[f32]| {
            let out = sac_act(&al, f, &shape, &obs, &eps);
            let q1 = ddpg_critic(&cl, &c1, &shape, &obs, &out.action);
            let q2 = ddpg_critic(&cl, &c2, &shape, &obs, &out.action);
            (0..5)
                .map(|r| inv_n * (alpha * out.logp[r] - q1[r].min(q2[r])))
                .sum::<f32>()
        };
        assert!((loss_of(&af) - loss).abs() < 1e-5);
        let direct = sac_act(&al, &af, &shape, &obs, &eps);
        assert!((direct.logp.iter().sum::<f32>() - logp_sum).abs() < 1e-4);
        let fd_eps = 2e-3f32;
        for i in (0..al.total()).step_by(al.total() / 30) {
            let mut fp = af.clone();
            fp[i] += fd_eps;
            let mut fm = af.clone();
            fm[i] -= fd_eps;
            let fd = (loss_of(&fp) - loss_of(&fm)) / (2.0 * fd_eps);
            let denom = fd.abs().max(grad[i].abs()).max(1e-2);
            assert!(
                (fd - grad[i]).abs() / denom < 0.1,
                "param {i}: fd={fd} analytic={}",
                grad[i]
            );
        }
    }
}
