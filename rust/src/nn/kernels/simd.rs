//! Explicit-SIMD kernel arms: AVX2/FMA (x86_64) and NEON (aarch64).
//!
//! Exact-mode kernels replicate the scalar arm's per-element operation
//! order and rounding exactly — broadcast multiply + separate add (never
//! FMA), including the `a == 0.0` row skip — so they are bitwise
//! identical to [`super::scalar`] for finite inputs at any lane width.
//! Fast-mode kernels use fused multiply-add (and, on AVX2, a 4x16
//! register-tiled main loop) and may differ from scalar by rounding.
//!
//! # Safety
//!
//! The AVX2 functions are `#[target_feature(enable = "avx2,fma")]` and
//! must only be called after `is_x86_feature_detected!` confirmed both
//! features — [`super`]'s dispatch (and [`super::override_lanes`]'s
//! fallback) is the sole caller and upholds this. NEON is baseline on
//! aarch64, so the neon module exposes safe wrappers. All pointer
//! arithmetic stays inside the slice bounds asserted by the `super::*_via`
//! entry points.

#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use super::super::KernelMode;
    use core::arch::x86_64::*;

    /// y[0..n] += s * x[0..n], scalar rounding order (mul then add).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy_exact(s: f32, x: *const f32, y: *mut f32, n: usize) {
        let vs = _mm256_set1_ps(s);
        let mut j = 0;
        while j + 8 <= n {
            let xv = _mm256_loadu_ps(x.add(j));
            let yv = _mm256_loadu_ps(y.add(j));
            _mm256_storeu_ps(y.add(j), _mm256_add_ps(yv, _mm256_mul_ps(vs, xv)));
            j += 8;
        }
        while j < n {
            *y.add(j) += s * *x.add(j);
            j += 1;
        }
    }

    /// y[0..n] += s * x[0..n], fused.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy_fma(s: f32, x: *const f32, y: *mut f32, n: usize) {
        let vs = _mm256_set1_ps(s);
        let mut j = 0;
        while j + 8 <= n {
            let xv = _mm256_loadu_ps(x.add(j));
            let yv = _mm256_loadu_ps(y.add(j));
            _mm256_storeu_ps(y.add(j), _mm256_fmadd_ps(vs, xv, yv));
            j += 8;
        }
        while j < n {
            *y.add(j) = s.mul_add(*x.add(j), *y.add(j));
            j += 1;
        }
    }

    /// out += a @ b (see [`super::super::matmul`] for shapes).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn matmul(
        mode: KernelMode,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        match mode {
            KernelMode::Exact => matmul_exact(a, b, out, m, k, n),
            KernelMode::Fast => matmul_tiled(a, b, out, m, k, n),
        }
    }

    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn matmul_exact(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        for i in 0..m {
            let orow = out.as_mut_ptr().add(i * n);
            for p in 0..k {
                let av = *a.get_unchecked(i * k + p);
                if av == 0.0 {
                    continue;
                }
                axpy_exact(av, b.as_ptr().add(p * n), orow, n);
            }
        }
    }

    /// Register-tiled fast GEMM: 4 output rows x 16 columns held in 8
    /// ymm accumulators across the whole k loop (each b strip is loaded
    /// once and feeds all 4 rows).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn matmul_tiled(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        let mut i = 0;
        while i + 4 <= m {
            let mut j = 0;
            while j + 16 <= n {
                let mut c00 = _mm256_loadu_ps(out.as_ptr().add(i * n + j));
                let mut c01 = _mm256_loadu_ps(out.as_ptr().add(i * n + j + 8));
                let mut c10 = _mm256_loadu_ps(out.as_ptr().add((i + 1) * n + j));
                let mut c11 = _mm256_loadu_ps(out.as_ptr().add((i + 1) * n + j + 8));
                let mut c20 = _mm256_loadu_ps(out.as_ptr().add((i + 2) * n + j));
                let mut c21 = _mm256_loadu_ps(out.as_ptr().add((i + 2) * n + j + 8));
                let mut c30 = _mm256_loadu_ps(out.as_ptr().add((i + 3) * n + j));
                let mut c31 = _mm256_loadu_ps(out.as_ptr().add((i + 3) * n + j + 8));
                for p in 0..k {
                    let b0 = _mm256_loadu_ps(b.as_ptr().add(p * n + j));
                    let b1 = _mm256_loadu_ps(b.as_ptr().add(p * n + j + 8));
                    let a0 = _mm256_set1_ps(*a.get_unchecked(i * k + p));
                    c00 = _mm256_fmadd_ps(a0, b0, c00);
                    c01 = _mm256_fmadd_ps(a0, b1, c01);
                    let a1 = _mm256_set1_ps(*a.get_unchecked((i + 1) * k + p));
                    c10 = _mm256_fmadd_ps(a1, b0, c10);
                    c11 = _mm256_fmadd_ps(a1, b1, c11);
                    let a2 = _mm256_set1_ps(*a.get_unchecked((i + 2) * k + p));
                    c20 = _mm256_fmadd_ps(a2, b0, c20);
                    c21 = _mm256_fmadd_ps(a2, b1, c21);
                    let a3 = _mm256_set1_ps(*a.get_unchecked((i + 3) * k + p));
                    c30 = _mm256_fmadd_ps(a3, b0, c30);
                    c31 = _mm256_fmadd_ps(a3, b1, c31);
                }
                _mm256_storeu_ps(out.as_mut_ptr().add(i * n + j), c00);
                _mm256_storeu_ps(out.as_mut_ptr().add(i * n + j + 8), c01);
                _mm256_storeu_ps(out.as_mut_ptr().add((i + 1) * n + j), c10);
                _mm256_storeu_ps(out.as_mut_ptr().add((i + 1) * n + j + 8), c11);
                _mm256_storeu_ps(out.as_mut_ptr().add((i + 2) * n + j), c20);
                _mm256_storeu_ps(out.as_mut_ptr().add((i + 2) * n + j + 8), c21);
                _mm256_storeu_ps(out.as_mut_ptr().add((i + 3) * n + j), c30);
                _mm256_storeu_ps(out.as_mut_ptr().add((i + 3) * n + j + 8), c31);
                j += 16;
            }
            // column tail for this 4-row band
            if j < n {
                for r in i..i + 4 {
                    for p in 0..k {
                        let av = *a.get_unchecked(r * k + p);
                        if av == 0.0 {
                            continue;
                        }
                        axpy_fma(
                            av,
                            b.as_ptr().add(p * n + j),
                            out.as_mut_ptr().add(r * n + j),
                            n - j,
                        );
                    }
                }
            }
            i += 4;
        }
        // row tail
        while i < m {
            let orow = out.as_mut_ptr().add(i * n);
            for p in 0..k {
                let av = *a.get_unchecked(i * k + p);
                if av == 0.0 {
                    continue;
                }
                axpy_fma(av, b.as_ptr().add(p * n), orow, n);
            }
            i += 1;
        }
    }

    /// out += a^T @ b (see [`super::super::matmul_tn`] for shapes).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn matmul_tn(
        mode: KernelMode,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for p in 0..k {
            let brow = b.as_ptr().add(p * n);
            for i in 0..m {
                let av = *a.get_unchecked(p * m + i);
                if av == 0.0 {
                    continue;
                }
                let orow = out.as_mut_ptr().add(i * n);
                match mode {
                    KernelMode::Exact => axpy_exact(av, brow, orow, n),
                    KernelMode::Fast => axpy_fma(av, brow, orow, n),
                }
            }
        }
    }

    /// out += a @ b^T, fast mode only (vectorized dot + horizontal sum;
    /// exact mode routes to scalar upstream).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn matmul_nt_fast(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = a.as_ptr().add(i * k);
            for j in 0..n {
                let brow = b.as_ptr().add(j * k);
                let mut acc = _mm256_setzero_ps();
                let mut p = 0;
                while p + 8 <= k {
                    acc = _mm256_fmadd_ps(
                        _mm256_loadu_ps(arow.add(p)),
                        _mm256_loadu_ps(brow.add(p)),
                        acc,
                    );
                    p += 8;
                }
                let mut dot = hsum(acc);
                while p < k {
                    dot = (*arow.add(p)).mul_add(*brow.add(p), dot);
                    p += 1;
                }
                *out.get_unchecked_mut(i * n + j) += dot;
            }
        }
    }

    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// x[r,:] += bias (elementwise — exact-safe in both modes).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn add_bias(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
        for r in 0..rows {
            let row = x.as_mut_ptr().add(r * cols);
            let mut j = 0;
            while j + 8 <= cols {
                let v = _mm256_add_ps(
                    _mm256_loadu_ps(row.add(j)),
                    _mm256_loadu_ps(bias.as_ptr().add(j)),
                );
                _mm256_storeu_ps(row.add(j), v);
                j += 8;
            }
            while j < cols {
                *row.add(j) += *bias.get_unchecked(j);
                j += 1;
            }
        }
    }

    /// y += a * x elementwise — the public [`super::super::axpy`] kernel
    /// (mul-then-add in every mode: elementwise ops have no reduction to
    /// reorder, so this arm is bitwise-equal to scalar by construction).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        axpy_exact(a, x.as_ptr(), y.as_mut_ptr(), y.len());
    }

    /// y = clamp(y + a * x, lo, hi). Exact for non-NaN inputs: min/max
    /// operand order mirrors scalar `f32::clamp` for finite values.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy_clamp(a: f32, x: &[f32], y: &mut [f32], lo: f32, hi: f32) {
        let n = y.len();
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let vs = _mm256_set1_ps(a);
        let vlo = _mm256_set1_ps(lo);
        let vhi = _mm256_set1_ps(hi);
        let mut j = 0;
        while j + 8 <= n {
            let sum = _mm256_add_ps(
                _mm256_loadu_ps(yp.add(j)),
                _mm256_mul_ps(vs, _mm256_loadu_ps(xp.add(j))),
            );
            _mm256_storeu_ps(yp.add(j), _mm256_max_ps(_mm256_min_ps(sum, vhi), vlo));
            j += 8;
        }
        while j < n {
            *yp.add(j) = (*yp.add(j) + a * *xp.add(j)).clamp(lo, hi);
            j += 1;
        }
    }

    /// x = max(x, 0). Operand order mirrors scalar `v.max(0.0)`:
    /// `vmaxps(v, 0)` returns 0 when v is NaN.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn relu(x: &mut [f32]) {
        let zero = _mm256_setzero_ps();
        let ptr = x.as_mut_ptr();
        let len = x.len();
        let mut j = 0;
        while j + 8 <= len {
            _mm256_storeu_ps(ptr.add(j), _mm256_max_ps(_mm256_loadu_ps(ptr.add(j)), zero));
            j += 8;
        }
        while j < len {
            *ptr.add(j) = (*ptr.add(j)).max(0.0);
            j += 1;
        }
    }

    /// int8 GEMM + dequant + bias (see [`super::super::matmul_q8`]).
    /// 16 columns per strip: i8 b-row loads widen to i16, multiply by the
    /// broadcast a (products fit i16 at ±127), widen-accumulate into two
    /// 8-lane i32 registers, dequantize once per strip.
    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn matmul_q8(
        aq: &[i8],
        ascale: &[f32],
        bq: &[i8],
        bscale: &[f32],
        bias: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let sa = *ascale.get_unchecked(i);
            let vsa = _mm256_set1_ps(sa);
            let mut j = 0;
            while j + 16 <= n {
                let mut acc0 = _mm256_setzero_si256();
                let mut acc1 = _mm256_setzero_si256();
                for p in 0..k {
                    let av = *aq.get_unchecked(i * k + p);
                    if av == 0 {
                        continue;
                    }
                    let a16 = _mm256_set1_epi16(av as i16);
                    let b8 = _mm_loadu_si128(bq.as_ptr().add(p * n + j) as *const __m128i);
                    let b16 = _mm256_cvtepi8_epi16(b8);
                    let prod = _mm256_mullo_epi16(a16, b16);
                    acc0 = _mm256_add_epi32(
                        acc0,
                        _mm256_cvtepi16_epi32(_mm256_castsi256_si128(prod)),
                    );
                    acc1 = _mm256_add_epi32(
                        acc1,
                        _mm256_cvtepi16_epi32(_mm256_extracti128_si256(prod, 1)),
                    );
                }
                // out = acc * (sa * sb) + bias — same rounding as scalar
                let s0 = _mm256_mul_ps(vsa, _mm256_loadu_ps(bscale.as_ptr().add(j)));
                let s1 = _mm256_mul_ps(vsa, _mm256_loadu_ps(bscale.as_ptr().add(j + 8)));
                let o0 = _mm256_add_ps(
                    _mm256_mul_ps(_mm256_cvtepi32_ps(acc0), s0),
                    _mm256_loadu_ps(bias.as_ptr().add(j)),
                );
                let o1 = _mm256_add_ps(
                    _mm256_mul_ps(_mm256_cvtepi32_ps(acc1), s1),
                    _mm256_loadu_ps(bias.as_ptr().add(j + 8)),
                );
                _mm256_storeu_ps(out.as_mut_ptr().add(i * n + j), o0);
                _mm256_storeu_ps(out.as_mut_ptr().add(i * n + j + 8), o1);
                j += 16;
            }
            while j < n {
                let mut acc = 0i32;
                for p in 0..k {
                    let av = *aq.get_unchecked(i * k + p) as i32;
                    if av == 0 {
                        continue;
                    }
                    acc += av * *bq.get_unchecked(p * n + j) as i32;
                }
                *out.get_unchecked_mut(i * n + j) =
                    acc as f32 * (sa * *bscale.get_unchecked(j)) + *bias.get_unchecked(j);
                j += 1;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub mod neon {
    use super::super::KernelMode;
    use core::arch::aarch64::*;

    /// y[0..n] += s * x[0..n], scalar rounding order (mul then add).
    #[inline]
    fn axpy_exact(s: f32, x: &[f32], y: &mut [f32], n: usize) {
        unsafe {
            let vs = vdupq_n_f32(s);
            let xp = x.as_ptr();
            let yp = y.as_mut_ptr();
            let mut j = 0;
            while j + 4 <= n {
                let xv = vld1q_f32(xp.add(j));
                let yv = vld1q_f32(yp.add(j));
                vst1q_f32(yp.add(j), vaddq_f32(yv, vmulq_f32(vs, xv)));
                j += 4;
            }
            while j < n {
                *yp.add(j) += s * *xp.add(j);
                j += 1;
            }
        }
    }

    /// y[0..n] += s * x[0..n], fused.
    #[inline]
    fn axpy_fma(s: f32, x: &[f32], y: &mut [f32], n: usize) {
        unsafe {
            let vs = vdupq_n_f32(s);
            let xp = x.as_ptr();
            let yp = y.as_mut_ptr();
            let mut j = 0;
            while j + 4 <= n {
                let xv = vld1q_f32(xp.add(j));
                let yv = vld1q_f32(yp.add(j));
                vst1q_f32(yp.add(j), vfmaq_f32(yv, vs, xv));
                j += 4;
            }
            while j < n {
                *yp.add(j) = s.mul_add(*xp.add(j), *yp.add(j));
                j += 1;
            }
        }
    }

    /// out += a @ b (see [`super::super::matmul`] for shapes).
    pub fn matmul(
        mode: KernelMode,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                match mode {
                    KernelMode::Exact => axpy_exact(av, brow, orow, n),
                    KernelMode::Fast => axpy_fma(av, brow, orow, n),
                }
            }
        }
    }

    /// out += a^T @ b (see [`super::super::matmul_tn`] for shapes).
    pub fn matmul_tn(
        mode: KernelMode,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for p in 0..k {
            let brow = &b[p * n..(p + 1) * n];
            for i in 0..m {
                let av = a[p * m + i];
                if av == 0.0 {
                    continue;
                }
                let orow = &mut out[i * n..(i + 1) * n];
                match mode {
                    KernelMode::Exact => axpy_exact(av, brow, orow, n),
                    KernelMode::Fast => axpy_fma(av, brow, orow, n),
                }
            }
        }
    }

    /// out += a @ b^T, fast mode only (4-lane dot + horizontal sum).
    pub fn matmul_nt_fast(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        unsafe {
            for i in 0..m {
                let arow = a.as_ptr().add(i * k);
                for j in 0..n {
                    let brow = b.as_ptr().add(j * k);
                    let mut acc = vdupq_n_f32(0.0);
                    let mut p = 0;
                    while p + 4 <= k {
                        acc = vfmaq_f32(acc, vld1q_f32(arow.add(p)), vld1q_f32(brow.add(p)));
                        p += 4;
                    }
                    let mut dot = vaddvq_f32(acc);
                    while p < k {
                        dot = (*arow.add(p)).mul_add(*brow.add(p), dot);
                        p += 1;
                    }
                    out[i * n + j] += dot;
                }
            }
        }
    }

    /// x[r,:] += bias (elementwise — exact-safe in both modes).
    pub fn add_bias(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
        unsafe {
            let bp = bias.as_ptr();
            for r in 0..rows {
                let row = x.as_mut_ptr().add(r * cols);
                let mut j = 0;
                while j + 4 <= cols {
                    vst1q_f32(row.add(j), vaddq_f32(vld1q_f32(row.add(j)), vld1q_f32(bp.add(j))));
                    j += 4;
                }
                while j < cols {
                    *row.add(j) += *bp.add(j);
                    j += 1;
                }
            }
        }
    }

    /// y += a * x elementwise — the public [`super::super::axpy`] kernel
    /// (mul-then-add in every mode; bitwise-equal to scalar).
    pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = y.len();
        axpy_exact(a, x, y, n);
    }

    /// y = clamp(y + a * x, lo, hi). Exact for non-NaN inputs: min/max
    /// operand order mirrors scalar `f32::clamp` for finite values.
    pub fn axpy_clamp(a: f32, x: &[f32], y: &mut [f32], lo: f32, hi: f32) {
        unsafe {
            let n = y.len();
            let xp = x.as_ptr();
            let yp = y.as_mut_ptr();
            let vs = vdupq_n_f32(a);
            let vlo = vdupq_n_f32(lo);
            let vhi = vdupq_n_f32(hi);
            let mut j = 0;
            while j + 4 <= n {
                let sum = vaddq_f32(vld1q_f32(yp.add(j)), vmulq_f32(vs, vld1q_f32(xp.add(j))));
                vst1q_f32(yp.add(j), vmaxnmq_f32(vminnmq_f32(sum, vhi), vlo));
                j += 4;
            }
            while j < n {
                *yp.add(j) = (*yp.add(j) + a * *xp.add(j)).clamp(lo, hi);
                j += 1;
            }
        }
    }

    /// x = max(x, 0). `vmaxnmq` follows IEEE maxNum like scalar
    /// `v.max(0.0)` (NaN input yields 0).
    pub fn relu(x: &mut [f32]) {
        unsafe {
            let zero = vdupq_n_f32(0.0);
            let ptr = x.as_mut_ptr();
            let len = x.len();
            let mut j = 0;
            while j + 4 <= len {
                vst1q_f32(ptr.add(j), vmaxnmq_f32(vld1q_f32(ptr.add(j)), zero));
                j += 4;
            }
            while j < len {
                *ptr.add(j) = (*ptr.add(j)).max(0.0);
                j += 1;
            }
        }
    }

    /// int8 GEMM + dequant + bias (see [`super::super::matmul_q8`]).
    /// 8 columns per strip: `vmull_s8` widens the i8 products to i16,
    /// then widening adds accumulate into two 4-lane i32 registers.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_q8(
        aq: &[i8],
        ascale: &[f32],
        bq: &[i8],
        bscale: &[f32],
        bias: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        unsafe {
            for i in 0..m {
                let sa = ascale[i];
                let vsa = vdupq_n_f32(sa);
                let mut j = 0;
                while j + 8 <= n {
                    let mut acc0 = vdupq_n_s32(0);
                    let mut acc1 = vdupq_n_s32(0);
                    for p in 0..k {
                        let av = aq[i * k + p];
                        if av == 0 {
                            continue;
                        }
                        let a8 = vdup_n_s8(av);
                        let b8 = vld1_s8(bq.as_ptr().add(p * n + j));
                        let prod = vmull_s8(a8, b8);
                        acc0 = vaddw_s16(acc0, vget_low_s16(prod));
                        acc1 = vaddw_s16(acc1, vget_high_s16(prod));
                    }
                    let s0 = vmulq_f32(vsa, vld1q_f32(bscale.as_ptr().add(j)));
                    let s1 = vmulq_f32(vsa, vld1q_f32(bscale.as_ptr().add(j + 4)));
                    let o0 = vaddq_f32(
                        vmulq_f32(vcvtq_f32_s32(acc0), s0),
                        vld1q_f32(bias.as_ptr().add(j)),
                    );
                    let o1 = vaddq_f32(
                        vmulq_f32(vcvtq_f32_s32(acc1), s1),
                        vld1q_f32(bias.as_ptr().add(j + 4)),
                    );
                    vst1q_f32(out.as_mut_ptr().add(i * n + j), o0);
                    vst1q_f32(out.as_mut_ptr().add(i * n + j + 4), o1);
                    j += 8;
                }
                while j < n {
                    let mut acc = 0i32;
                    for p in 0..k {
                        let av = aq[i * k + p] as i32;
                        if av == 0 {
                            continue;
                        }
                        acc += av * bq[p * n + j] as i32;
                    }
                    out[i * n + j] = acc as f32 * (sa * bscale[j]) + bias[j];
                    j += 1;
                }
            }
        }
    }
}
