//! Scalar reference kernels — the always-correct fallback arm and the
//! bitwise oracle the SIMD arms are tested against.
//!
//! These are the exact loops `nn::tensor` shipped before the kernel
//! layer existed (same iteration order, same `a == 0.0` skip, same
//! per-element rounding), so routing through this arm reproduces the
//! pre-kernel results bit for bit.

/// out += a @ b. a:[m,k], b:[k,n], out:[m,n]; ikj order for locality.
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out += a^T @ b. a:[k,m], b:[k,n], out:[m,n] (no transpose alloc).
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out += a @ b^T. a:[m,k], b:[n,k], out:[m,n]: sequential dot products
/// (the exact-mode reduction order; see the module docs).
pub fn matmul_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            out[i * n + j] += acc;
        }
    }
}

/// x[r,:] += bias for every row.
pub fn add_bias(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    for r in 0..rows {
        for (v, b) in x[r * cols..(r + 1) * cols].iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// x = max(x, 0) elementwise.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.max(0.0);
    }
}

/// y += a * x elementwise (mul-then-add — the exact rounding order every
/// arm reproduces; the batched env integrators are built on this).
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// y = clamp(y + a * x, lo, hi) elementwise (the saturating integrator
/// step: velocity updates with physical speed limits).
pub fn axpy_clamp(a: f32, x: &[f32], y: &mut [f32], lo: f32, hi: f32) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv = (*yv + a * xv).clamp(lo, hi);
    }
}

/// int8 GEMM + dequant + bias (see [`super::matmul_q8`]). kj-inner order
/// with an i32 accumulator row so `b` streams row-wise like the f32 path.
#[allow(clippy::too_many_arguments)]
pub fn matmul_q8(
    aq: &[i8],
    ascale: &[f32],
    bq: &[i8],
    bscale: &[f32],
    bias: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let mut acc = vec![0i32; n];
    for i in 0..m {
        acc.fill(0);
        let arow = &aq[i * k..(i + 1) * k];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &bq[p * n..(p + 1) * n];
            for (o, &bv) in acc.iter_mut().zip(brow) {
                *o += av * bv as i32;
            }
        }
        let sa = ascale[i];
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            // mul-then-add, the same rounding sequence as the SIMD arms
            orow[j] = acc[j] as f32 * (sa * bscale[j]) + bias[j];
        }
    }
}
