//! Arch-dispatched CPU microkernels for the native backend's hot loops.
//!
//! Every dense op the native MLP touches — the three GEMM variants, bias
//! add, activations, and the int8 quantized path — lives here as a
//! slice-based kernel with (up to) three implementations:
//!
//! * **scalar** ([`scalar`]): the always-correct reference, byte-for-byte
//!   the same loops `nn::tensor` shipped before this layer existed;
//! * **AVX2/FMA** (`simd::avx2`, x86_64): 8-wide `std::arch` kernels,
//!   compiled unconditionally and selected at runtime via
//!   `is_x86_feature_detected!`;
//! * **NEON** (`simd::neon`, aarch64): 4-wide kernels (NEON is baseline
//!   on aarch64, so no feature probe is needed).
//!
//! # Dispatch rules
//!
//! The active lane set ([`Lanes`]) is detected **once** per process, on
//! first use: AVX2+FMA on x86_64 when the CPU has both, NEON on aarch64,
//! scalar everywhere else. The `WALLE_KERNELS` environment variable
//! overrides detection (`scalar` forces the portable fallback — this is
//! the CI "portable leg"; `simd`/`auto` keep auto-detection). Benches and
//! single-threaded harnesses may also call [`override_lanes`] /
//! [`set_mode`]; both are process-global, so concurrent tests must use
//! the explicit `*_via` entry points instead of flipping globals.
//!
//! # Exact vs fast mode
//!
//! [`KernelMode::Exact`] (the default, `--kernels exact`) guarantees the
//! SIMD arm is **bitwise identical** to the scalar reference for finite
//! inputs: vector kernels keep the scalar loop's per-element operation
//! order and rounding (broadcast multiply + separate add — never FMA),
//! including the `a == 0.0` row skip, and ops whose scalar form is a
//! sequential reduction (`matmul_nt`'s dot products, the Gaussian logp
//! row sums) stay scalar. This is what keeps the cross-shard/cross-flip
//! bitwise determinism suite green regardless of the machine's lane
//! width. [`KernelMode::Fast`] (`--kernels fast`) lifts the rounding
//! contract: GEMMs use fused multiply-add and a register-tiled main loop
//! (4 rows x 2 vectors on AVX2), and `matmul_nt` vectorizes its dot
//! products with a lane-reordered horizontal sum. Results differ from
//! scalar only by floating-point reassociation/fusion (empirically
//! ~1e-6 relative for the 64-wide policy nets; asserted by the parity
//! suite at 1e-4).
//!
//! `tanh` always routes through libm's `f32::tanh` in both modes — a
//! polynomial SIMD tanh would silently change every activation bit.
//!
//! # Shape preconditions and alignment
//!
//! All matrices are dense row-major `&[f32]` with no padding: `a` is
//! `[m, k]`, `b` is `[k, n]` (or as documented per variant), `out` is
//! `[m, n]`. Lengths are asserted at the public entry points. GEMMs
//! **accumulate** (`out +=`); pass a zeroed buffer for a plain product.
//! No alignment is required — kernels use unaligned loads, which cost
//! nothing on the targeted microarchitectures; callers should still
//! prefer freshly-allocated (16-byte-aligned) buffers.
//!
//! # int8 path
//!
//! [`matmul_q8`] computes `out[i,j] = (Σ_p aq[i,p]·bq[p,j]) · as[i]·bs[j]
//! + bias[j]` with i32 accumulation — exact integer arithmetic, so the
//! scalar and SIMD arms agree bitwise (the dequant epilogue uses the same
//! multiply-then-add rounding on both). Symmetric quantization clamps to
//! ±127 (never -128), so every product fits i16 and i32 accumulation is
//! safe for `k < 2^31 / 127^2 ≈ 133k` — asserted. Weights quantize
//! per-output-column ([`quantize_cols`]), activations per-row at call
//! time ([`quantize_rows`]).

use std::sync::atomic::{AtomicU8, Ordering};

pub mod scalar;
pub mod simd;

/// Rounding contract for the f32 SIMD kernels. See the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// SIMD arm is bitwise identical to scalar (default).
    Exact,
    /// FMA + register tiling + vectorized reductions; reassociation
    /// allowed.
    Fast,
}

/// Which kernel arm executes. `Avx2` implies FMA is also available.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lanes {
    Scalar,
    Avx2,
    Neon,
}

impl Lanes {
    pub fn name(self) -> &'static str {
        match self {
            Lanes::Scalar => "scalar",
            Lanes::Avx2 => "avx2",
            Lanes::Neon => "neon",
        }
    }
}

const LANES_UNSET: u8 = u8::MAX;
static LANES: AtomicU8 = AtomicU8::new(LANES_UNSET);
static MODE: AtomicU8 = AtomicU8::new(0); // 0 = Exact, 1 = Fast

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Lanes {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        Lanes::Avx2
    } else {
        Lanes::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Lanes {
    Lanes::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Lanes {
    Lanes::Scalar
}

fn detect() -> Lanes {
    match std::env::var("WALLE_KERNELS").ok().as_deref() {
        Some("scalar") => Lanes::Scalar,
        // "simd"/"auto"/unset/anything else: auto-detect (unknown values
        // must not silently disable SIMD in production)
        _ => detect_arch(),
    }
}

fn lanes_to_u8(l: Lanes) -> u8 {
    match l {
        Lanes::Scalar => 0,
        Lanes::Avx2 => 1,
        Lanes::Neon => 2,
    }
}

fn lanes_from_u8(v: u8) -> Lanes {
    match v {
        1 => Lanes::Avx2,
        2 => Lanes::Neon,
        _ => Lanes::Scalar,
    }
}

/// The process-wide active lane set (detected once, on first use).
pub fn active() -> Lanes {
    let v = LANES.load(Ordering::Relaxed);
    if v != LANES_UNSET {
        return lanes_from_u8(v);
    }
    let detected = detect();
    LANES.store(lanes_to_u8(detected), Ordering::Relaxed);
    detected
}

/// Force a lane set (benches / single-threaded harnesses only; see the
/// module docs). Requests for an arm the CPU can't run fall back to
/// scalar, so this can never select an unsound path.
pub fn override_lanes(l: Lanes) {
    let safe = match l {
        Lanes::Scalar => Lanes::Scalar,
        other => {
            if other == detect_arch() {
                other
            } else {
                Lanes::Scalar
            }
        }
    };
    LANES.store(lanes_to_u8(safe), Ordering::Relaxed);
}

/// The process-wide rounding contract (default [`KernelMode::Exact`]).
pub fn mode() -> KernelMode {
    if MODE.load(Ordering::Relaxed) == 0 {
        KernelMode::Exact
    } else {
        KernelMode::Fast
    }
}

/// Set the rounding contract (applied by the orchestrator from
/// `--kernels` before any worker thread starts).
pub fn set_mode(m: KernelMode) {
    MODE.store(if m == KernelMode::Exact { 0 } else { 1 }, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// f32 GEMM family
// ---------------------------------------------------------------------------

/// out += a @ b. a:[m,k], b:[k,n], out:[m,n].
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_via(active(), mode(), a, b, out, m, k, n);
}

/// out += a^T @ b. a:[k,m], b:[k,n], out:[m,n].
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_tn_via(active(), mode(), a, b, out, m, k, n);
}

/// out += a @ b^T. a:[m,k], b:[n,k], out:[m,n].
pub fn matmul_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_nt_via(active(), mode(), a, b, out, m, k, n);
}

/// x[r,:] += bias for every row. Exact-safe in every arm (elementwise).
pub fn add_bias(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    add_bias_via(active(), x, bias, rows, cols);
}

/// x = max(x, 0) elementwise. Exact-safe for non-NaN inputs.
pub fn relu_inplace(x: &mut [f32]) {
    relu_via(active(), x);
}

/// x = tanh(x) elementwise — always libm scalar (see module docs).
pub fn tanh_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// y += a * x elementwise — the column integrator of the batched env
/// engine (`env::batch`): one call advances an `[M]`-wide state column by
/// `dt * derivative`. Elementwise mul-then-add has no reduction to
/// reorder, so every arm is bitwise identical to scalar in BOTH modes.
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    axpy_via(active(), a, x, y);
}

/// y = clamp(y + a * x, lo, hi) elementwise — the saturating integrator
/// (velocity columns with physical speed limits). Bitwise identical to
/// scalar in every arm for non-NaN inputs.
pub fn axpy_clamp(a: f32, x: &[f32], y: &mut [f32], lo: f32, hi: f32) {
    axpy_clamp_via(active(), a, x, y, lo, hi);
}

/// [`axpy`] with explicit dispatch (parity tests, benches).
pub fn axpy_via(lanes: Lanes, a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy: x/y length mismatch");
    match lanes {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { simd::avx2::axpy(a, x, y) },
        #[cfg(target_arch = "aarch64")]
        Lanes::Neon => simd::neon::axpy(a, x, y),
        _ => scalar::axpy(a, x, y),
    }
}

/// [`axpy_clamp`] with explicit dispatch (parity tests, benches).
pub fn axpy_clamp_via(lanes: Lanes, a: f32, x: &[f32], y: &mut [f32], lo: f32, hi: f32) {
    assert_eq!(x.len(), y.len(), "axpy_clamp: x/y length mismatch");
    assert!(lo <= hi, "axpy_clamp: lo > hi");
    match lanes {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { simd::avx2::axpy_clamp(a, x, y, lo, hi) },
        #[cfg(target_arch = "aarch64")]
        Lanes::Neon => simd::neon::axpy_clamp(a, x, y, lo, hi),
        _ => scalar::axpy_clamp(a, x, y, lo, hi),
    }
}

/// [`matmul`] with explicit dispatch (parity tests, benches).
#[allow(clippy::too_many_arguments)]
pub fn matmul_via(
    lanes: Lanes,
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "matmul: bad a len");
    assert_eq!(b.len(), k * n, "matmul: bad b len");
    assert_eq!(out.len(), m * n, "matmul: bad out len");
    match lanes {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { simd::avx2::matmul(mode, a, b, out, m, k, n) },
        #[cfg(target_arch = "aarch64")]
        Lanes::Neon => simd::neon::matmul(mode, a, b, out, m, k, n),
        _ => scalar::matmul(a, b, out, m, k, n),
    }
}

/// [`matmul_tn`] with explicit dispatch (parity tests, benches).
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn_via(
    lanes: Lanes,
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), k * m, "matmul_tn: bad a len");
    assert_eq!(b.len(), k * n, "matmul_tn: bad b len");
    assert_eq!(out.len(), m * n, "matmul_tn: bad out len");
    match lanes {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { simd::avx2::matmul_tn(mode, a, b, out, m, k, n) },
        #[cfg(target_arch = "aarch64")]
        Lanes::Neon => simd::neon::matmul_tn(mode, a, b, out, m, k, n),
        _ => scalar::matmul_tn(a, b, out, m, k, n),
    }
}

/// [`matmul_nt`] with explicit dispatch. In exact mode every arm runs the
/// scalar dot products (a SIMD reduction would reorder the sum).
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_via(
    lanes: Lanes,
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "matmul_nt: bad a len");
    assert_eq!(b.len(), n * k, "matmul_nt: bad b len");
    assert_eq!(out.len(), m * n, "matmul_nt: bad out len");
    if mode == KernelMode::Exact {
        return scalar::matmul_nt(a, b, out, m, k, n);
    }
    match lanes {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { simd::avx2::matmul_nt_fast(a, b, out, m, k, n) },
        #[cfg(target_arch = "aarch64")]
        Lanes::Neon => simd::neon::matmul_nt_fast(a, b, out, m, k, n),
        _ => scalar::matmul_nt(a, b, out, m, k, n),
    }
}

/// [`add_bias`] with explicit dispatch.
pub fn add_bias_via(lanes: Lanes, x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "add_bias: bad x len");
    assert_eq!(bias.len(), cols, "add_bias: bad bias len");
    match lanes {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { simd::avx2::add_bias(x, bias, rows, cols) },
        #[cfg(target_arch = "aarch64")]
        Lanes::Neon => simd::neon::add_bias(x, bias, rows, cols),
        _ => scalar::add_bias(x, bias, rows, cols),
    }
}

/// [`relu_inplace`] with explicit dispatch.
pub fn relu_via(lanes: Lanes, x: &mut [f32]) {
    match lanes {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { simd::avx2::relu(x) },
        #[cfg(target_arch = "aarch64")]
        Lanes::Neon => simd::neon::relu(x),
        _ => scalar::relu(x),
    }
}

// ---------------------------------------------------------------------------
// int8 quantized path
// ---------------------------------------------------------------------------

/// Symmetric per-row quantization: `q[r,c] = round(x[r,c] * 127/maxabs_r)`
/// clamped to ±127, `scales[r] = maxabs_r / 127` (0 for an all-zero row).
pub fn quantize_rows(x: &[f32], rows: usize, cols: usize, q: &mut [i8], scales: &mut [f32]) {
    assert_eq!(x.len(), rows * cols, "quantize_rows: bad x len");
    assert_eq!(q.len(), rows * cols, "quantize_rows: bad q len");
    assert_eq!(scales.len(), rows, "quantize_rows: bad scales len");
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let maxabs = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let (scale, inv) = if maxabs > 0.0 {
            (maxabs / 127.0, 127.0 / maxabs)
        } else {
            (0.0, 0.0)
        };
        scales[r] = scale;
        for (qv, &v) in q[r * cols..(r + 1) * cols].iter_mut().zip(row) {
            *qv = ((v * inv).round() as i32).clamp(-127, 127) as i8;
        }
    }
}

/// Symmetric per-column quantization of a `[k, n]` row-major weight
/// matrix: column `j` gets `scales[j] = maxabs_j / 127`.
pub fn quantize_cols(w: &[f32], k: usize, n: usize, q: &mut [i8], scales: &mut [f32]) {
    assert_eq!(w.len(), k * n, "quantize_cols: bad w len");
    assert_eq!(q.len(), k * n, "quantize_cols: bad q len");
    assert_eq!(scales.len(), n, "quantize_cols: bad scales len");
    for j in 0..n {
        let mut maxabs = 0.0f32;
        for p in 0..k {
            maxabs = maxabs.max(w[p * n + j].abs());
        }
        let (scale, inv) = if maxabs > 0.0 {
            (maxabs / 127.0, 127.0 / maxabs)
        } else {
            (0.0, 0.0)
        };
        scales[j] = scale;
        for p in 0..k {
            q[p * n + j] = ((w[p * n + j] * inv).round() as i32).clamp(-127, 127) as i8;
        }
    }
}

/// int8 GEMM + dequant + bias:
/// `out[i,j] = (Σ_p aq[i,p]·bq[p,j]) · ascale[i]·bscale[j] + bias[j]`.
/// aq:[m,k] (per-row scales), bq:[k,n] (per-col scales), out:[m,n]
/// (overwritten, not accumulated). Scalar and SIMD arms agree bitwise.
#[allow(clippy::too_many_arguments)]
pub fn matmul_q8(
    aq: &[i8],
    ascale: &[f32],
    bq: &[i8],
    bscale: &[f32],
    bias: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    matmul_q8_via(active(), aq, ascale, bq, bscale, bias, out, m, k, n);
}

/// [`matmul_q8`] with explicit dispatch (parity tests, benches).
#[allow(clippy::too_many_arguments)]
pub fn matmul_q8_via(
    lanes: Lanes,
    aq: &[i8],
    ascale: &[f32],
    bq: &[i8],
    bscale: &[f32],
    bias: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(aq.len(), m * k, "matmul_q8: bad aq len");
    assert_eq!(ascale.len(), m, "matmul_q8: bad ascale len");
    assert_eq!(bq.len(), k * n, "matmul_q8: bad bq len");
    assert_eq!(bscale.len(), n, "matmul_q8: bad bscale len");
    assert_eq!(bias.len(), n, "matmul_q8: bad bias len");
    assert_eq!(out.len(), m * n, "matmul_q8: bad out len");
    // ±127 products fit i16; i32 accumulation is safe up to this depth
    assert!(k < (i32::MAX as usize) / (127 * 127), "matmul_q8: k too deep for i32 acc");
    match lanes {
        #[cfg(target_arch = "x86_64")]
        Lanes::Avx2 => unsafe { simd::avx2::matmul_q8(aq, ascale, bq, bscale, bias, out, m, k, n) },
        #[cfg(target_arch = "aarch64")]
        Lanes::Neon => simd::neon::matmul_q8(aq, ascale, bq, bscale, bias, out, m, k, n),
        _ => scalar::matmul_q8(aq, ascale, bq, bscale, bias, out, m, k, n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_vec(rng: &mut Pcg64, len: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        rng.fill_normal(&mut v);
        v
    }

    /// The in-process arm (whatever this machine dispatches to) must be
    /// bitwise identical to scalar in exact mode — the module's core
    /// guarantee, checked across odd shapes in tests/kernel_parity.rs.
    #[test]
    fn active_arm_matches_scalar_bitwise_in_exact_mode() {
        let mut rng = Pcg64::new(11);
        let (m, k, n) = (5, 17, 23);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut o_ref = vec![0.0f32; m * n];
        let mut o_act = vec![0.0f32; m * n];
        scalar::matmul(&a, &b, &mut o_ref, m, k, n);
        matmul_via(active(), KernelMode::Exact, &a, &b, &mut o_act, m, k, n);
        for (x, y) in o_ref.iter().zip(&o_act) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// The elementwise integrator kernels must be bitwise-equal to scalar
    /// on the active arm — in both modes (they carry no reduction, so the
    /// fast contract never relaxes them). Odd lengths exercise the tails.
    #[test]
    fn axpy_family_matches_scalar_bitwise_on_active_arm() {
        let mut rng = Pcg64::new(15);
        for len in [1usize, 4, 7, 8, 13, 64, 257] {
            let x = rand_vec(&mut rng, len);
            let y0 = rand_vec(&mut rng, len);
            let mut y_ref = y0.clone();
            let mut y_act = y0.clone();
            scalar::axpy(0.05, &x, &mut y_ref);
            axpy_via(active(), 0.05, &x, &mut y_act);
            for (a, b) in y_ref.iter().zip(&y_act) {
                assert_eq!(a.to_bits(), b.to_bits(), "axpy len {len}");
            }
            let mut y_ref = y0.clone();
            let mut y_act = y0;
            scalar::axpy_clamp(0.05, &x, &mut y_ref, -0.8, 0.8);
            axpy_clamp_via(active(), 0.05, &x, &mut y_act, -0.8, 0.8);
            for (a, b) in y_ref.iter().zip(&y_act) {
                assert_eq!(a.to_bits(), b.to_bits(), "axpy_clamp len {len}");
            }
        }
    }

    #[test]
    fn fast_mode_stays_close_to_scalar() {
        let mut rng = Pcg64::new(12);
        let (m, k, n) = (9, 33, 14);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut o_ref = vec![0.0f32; m * n];
        let mut o_fast = vec![0.0f32; m * n];
        scalar::matmul(&a, &b, &mut o_ref, m, k, n);
        matmul_via(active(), KernelMode::Fast, &a, &b, &mut o_fast, m, k, n);
        for (x, y) in o_ref.iter().zip(&o_fast) {
            assert!((x - y).abs() <= 1e-4 * x.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn quantize_round_trips_within_step() {
        let mut rng = Pcg64::new(13);
        let (rows, cols) = (4, 19);
        let x = rand_vec(&mut rng, rows * cols);
        let mut q = vec![0i8; rows * cols];
        let mut s = vec![0.0f32; rows];
        quantize_rows(&x, rows, cols, &mut q, &mut s);
        for r in 0..rows {
            for c in 0..cols {
                let deq = q[r * cols + c] as f32 * s[r];
                // symmetric round-to-nearest: error bounded by half a step
                assert!((deq - x[r * cols + c]).abs() <= 0.5 * s[r] + 1e-7);
            }
        }
    }

    #[test]
    fn q8_gemm_approximates_f32_gemm() {
        let mut rng = Pcg64::new(14);
        let (m, k, n) = (8, 32, 16);
        let a = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let bias = rand_vec(&mut rng, n);

        let mut exact = vec![0.0f32; m * n];
        scalar::matmul(&a, &w, &mut exact, m, k, n);
        for i in 0..m {
            for j in 0..n {
                exact[i * n + j] += bias[j];
            }
        }

        let (mut aq, mut a_s) = (vec![0i8; m * k], vec![0.0f32; m]);
        let (mut wq, mut w_s) = (vec![0i8; k * n], vec![0.0f32; n]);
        quantize_rows(&a, m, k, &mut aq, &mut a_s);
        quantize_cols(&w, k, n, &mut wq, &mut w_s);
        let mut got = vec![0.0f32; m * n];
        matmul_q8(&aq, &a_s, &wq, &w_s, &bias, &mut got, m, k, n);

        let scale: f32 = exact.iter().fold(0.0f32, |s, v| s.max(v.abs()));
        for (e, g) in exact.iter().zip(&got) {
            // int8 with per-row/per-col scales: ~1% of dynamic range
            assert!((e - g).abs() <= 0.02 * scale.max(1.0), "{e} vs {g}");
        }
    }

    #[test]
    fn zero_row_quantizes_to_zero_scale_and_zero_output() {
        let x = vec![0.0f32; 8];
        let mut q = vec![7i8; 8];
        let mut s = vec![1.0f32; 1];
        quantize_rows(&x, 1, 8, &mut q, &mut s);
        assert_eq!(s[0], 0.0);
        assert!(q.iter().all(|&v| v == 0));
    }

    #[test]
    fn override_falls_back_when_arm_unavailable() {
        // Neon can never be forced on x86_64 (and vice versa); the
        // override must degrade to scalar, not select an unsound arm.
        #[cfg(target_arch = "x86_64")]
        {
            override_lanes(Lanes::Neon);
            assert_eq!(active(), Lanes::Scalar);
        }
        #[cfg(target_arch = "aarch64")]
        {
            override_lanes(Lanes::Avx2);
            assert_eq!(active(), Lanes::Scalar);
        }
        override_lanes(detect());
        assert_eq!(active(), detect());
    }
}
