//! Flat-parameter layout: the Rust mirror of `python/compile/model.py`'s
//! `param_spec` / `actor_spec` / `critic_spec`.
//!
//! Both sides must agree byte-for-byte on (name, shape, offset, init) —
//! the AOT `meta.json` carries the Python side's layout and
//! `runtime::artifacts` cross-checks it against this module at startup, so
//! a drift fails fast instead of silently mis-slicing parameters.

use crate::util::rng::Pcg64;

/// Initialization scheme for one tensor (mirrors meta.json `init`).
#[derive(Debug, Clone, PartialEq)]
pub enum Init {
    Glorot,
    Zeros,
    Const(f32),
}

impl Init {
    pub fn parse(s: &str) -> Option<Init> {
        match s {
            "glorot" => Some(Init::Glorot),
            "zeros" => Some(Init::Zeros),
            _ => s.strip_prefix("const:").and_then(|v| v.parse().ok().map(Init::Const)),
        }
    }
}

/// One tensor inside a flat parameter vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub init: Init,
}

impl ParamEntry {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Ordered layout of a flat parameter vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamLayout {
    pub entries: Vec<ParamEntry>,
}

impl ParamLayout {
    pub fn total(&self) -> usize {
        self.entries.iter().map(|e| e.size()).sum()
    }

    pub fn find(&self, name: &str) -> Option<&ParamEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Slice of `flat` for entry `name`.
    pub fn view<'a>(&self, flat: &'a [f32], name: &str) -> Option<&'a [f32]> {
        let e = self.find(name)?;
        Some(&flat[e.offset..e.offset + e.size()])
    }

    /// Initialize a fresh flat parameter vector (Glorot / zeros / const —
    /// the same schemes as python `model.init_flat`, with WALL-E's own RNG).
    pub fn init_flat(&self, rng: &mut Pcg64) -> Vec<f32> {
        let mut flat = vec![0.0f32; self.total()];
        for e in &self.entries {
            let dst = &mut flat[e.offset..e.offset + e.size()];
            match e.init {
                Init::Zeros => {}
                Init::Const(v) => dst.fill(v),
                Init::Glorot => {
                    assert_eq!(e.shape.len(), 2, "glorot needs a 2-D tensor");
                    let (fi, fo) = (e.shape[0] as f32, e.shape[1] as f32);
                    let bound = (6.0 / (fi + fo)).sqrt();
                    rng.fill_uniform(dst, -bound, bound);
                }
            }
        }
        flat
    }
}

fn mlp_entries(
    prefix: &str,
    in_dim: usize,
    hidden: &[usize],
    out_dim: usize,
    offset: &mut usize,
    entries: &mut Vec<ParamEntry>,
) {
    let mut dims = vec![in_dim];
    dims.extend_from_slice(hidden);
    dims.push(out_dim);
    for i in 0..dims.len() - 1 {
        let (fi, fo) = (dims[i], dims[i + 1]);
        let name = if i < hidden.len() {
            format!("{prefix}/l{i}")
        } else {
            format!("{prefix}/out")
        };
        entries.push(ParamEntry {
            name: format!("{name}/w"),
            shape: vec![fi, fo],
            offset: *offset,
            init: Init::Glorot,
        });
        *offset += fi * fo;
        entries.push(ParamEntry {
            name: format!("{name}/b"),
            shape: vec![fo],
            offset: *offset,
            init: Init::Zeros,
        });
        *offset += fo;
    }
}

/// PPO layout: policy MLP, log_std, value MLP (== python `param_spec`).
pub fn ppo_layout(obs_dim: usize, act_dim: usize, hidden: &[usize]) -> ParamLayout {
    let mut entries = Vec::new();
    let mut off = 0;
    mlp_entries("pi", obs_dim, hidden, act_dim, &mut off, &mut entries);
    entries.push(ParamEntry {
        name: "pi/log_std".into(),
        shape: vec![act_dim],
        offset: off,
        init: Init::Const(-0.5),
    });
    off += act_dim;
    mlp_entries("vf", obs_dim, hidden, 1, &mut off, &mut entries);
    ParamLayout { entries }
}

/// DDPG actor layout (== python `actor_spec`).
pub fn actor_layout(obs_dim: usize, act_dim: usize, hidden: &[usize]) -> ParamLayout {
    let mut entries = Vec::new();
    let mut off = 0;
    mlp_entries("actor", obs_dim, hidden, act_dim, &mut off, &mut entries);
    ParamLayout { entries }
}

/// DDPG critic layout (== python `critic_spec`; input = concat(obs, act)).
pub fn critic_layout(obs_dim: usize, act_dim: usize, hidden: &[usize]) -> ParamLayout {
    let mut entries = Vec::new();
    let mut off = 0;
    mlp_entries("critic", obs_dim + act_dim, hidden, 1, &mut off, &mut entries);
    ParamLayout { entries }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn halfcheetah_count_matches_python() {
        // asserted on the python side in test_model.py as well
        let l = ppo_layout(17, 6, &[64, 64]);
        let pi = 17 * 64 + 64 + 64 * 64 + 64 + 64 * 6 + 6 + 6;
        let vf = 17 * 64 + 64 + 64 * 64 + 64 + 64 + 1;
        assert_eq!(l.total(), pi + vf);
    }

    #[test]
    fn offsets_contiguous() {
        let l = ppo_layout(3, 2, &[16, 16]);
        let mut off = 0;
        for e in &l.entries {
            assert_eq!(e.offset, off, "{}", e.name);
            off += e.size();
        }
        assert_eq!(off, l.total());
    }

    #[test]
    fn entry_names_match_python_order() {
        let l = ppo_layout(3, 1, &[8, 8]);
        let names: Vec<&str> = l.entries.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "pi/l0/w", "pi/l0/b", "pi/l1/w", "pi/l1/b", "pi/out/w", "pi/out/b",
                "pi/log_std",
                "vf/l0/w", "vf/l0/b", "vf/l1/w", "vf/l1/b", "vf/out/w", "vf/out/b",
            ]
        );
    }

    #[test]
    fn init_respects_schemes() {
        let l = ppo_layout(4, 2, &[8, 8]);
        let mut rng = Pcg64::new(0);
        let flat = l.init_flat(&mut rng);
        // log_std == -0.5 everywhere
        let ls = l.view(&flat, "pi/log_std").unwrap();
        assert!(ls.iter().all(|&v| (v + 0.5).abs() < 1e-6));
        // biases zero
        let b = l.view(&flat, "pi/l0/b").unwrap();
        assert!(b.iter().all(|&v| v == 0.0));
        // weights inside glorot bound and non-degenerate
        let w = l.view(&flat, "pi/l0/w").unwrap();
        let bound = (6.0f32 / (4.0 + 8.0)).sqrt();
        assert!(w.iter().all(|&v| v.abs() <= bound + 1e-6));
        assert!(w.iter().any(|&v| v.abs() > 0.01));
    }

    #[test]
    fn actor_critic_counts() {
        assert_eq!(
            actor_layout(17, 6, &[64, 64]).total(),
            17 * 64 + 64 + 64 * 64 + 64 + 64 * 6 + 6
        );
        assert_eq!(
            critic_layout(17, 6, &[64, 64]).total(),
            23 * 64 + 64 + 64 * 64 + 64 + 64 + 1
        );
    }

    #[test]
    fn init_parse_round_trip() {
        assert_eq!(Init::parse("glorot"), Some(Init::Glorot));
        assert_eq!(Init::parse("zeros"), Some(Init::Zeros));
        assert_eq!(Init::parse("const:-0.5"), Some(Init::Const(-0.5)));
        assert_eq!(Init::parse("bogus"), None);
    }
}
