//! Native neural-network substrate: flat-parameter layout, minimal dense
//! linear algebra, MLP forward/backward, and Adam.
//!
//! This is the pure-Rust mirror of the L2 JAX model (same math, same flat
//! layout) backing `runtime::NativeBackend`; the AOT/XLA path is
//! integration-tested against it.

pub mod adam;
pub mod kernels;
pub mod layout;
pub mod mlp;
pub mod quant;
pub mod tensor;
