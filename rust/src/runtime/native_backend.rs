//! Pure-Rust backend: the artifact-free mirror of the AOT/XLA path.
//!
//! Same math, same flat-parameter ABI (`nn::layout` == python `model.py`),
//! so a run can switch `--backend native|xla` and produce statistically
//! identical learning curves. Used by `cargo test` (no Python needed), the
//! quickstart example, and as the oracle in parity tests.

use super::{
    ActResult, ActorBackend, BackendFactory, DdpgActorBackend, DdpgBatch, DdpgLearnerBackend,
    DdpgTrainState, PpoLearnerBackend, PpoMinibatch, PpoTrainState,
};
use crate::algo::gae as gae_mod;
use crate::config::{DdpgCfg, PpoCfg};
use crate::nn::adam::{Adam, AdamCfg};
use crate::nn::layout::{actor_layout, critic_layout, ppo_layout, ParamLayout};
use crate::nn::mlp::{self, NetShape, PpoBatch, PpoLossCfg, PpoStats};
use crate::nn::tensor::Mat;
use crate::util::rng::Pcg64;

/// Factory for native backends.
pub struct NativeFactory {
    obs_dim: usize,
    act_dim: usize,
    hidden: Vec<usize>,
    ppo: PpoCfg,
    ddpg: DdpgCfg,
}

impl NativeFactory {
    pub fn new(
        obs_dim: usize,
        act_dim: usize,
        hidden: &[usize],
        ppo: PpoCfg,
        ddpg: DdpgCfg,
    ) -> Self {
        Self {
            obs_dim,
            act_dim,
            hidden: hidden.to_vec(),
            ppo,
            ddpg,
        }
    }

    fn shape(&self) -> NetShape {
        NetShape::new(self.obs_dim, self.act_dim, &self.hidden)
    }

    fn layout(&self) -> ParamLayout {
        ppo_layout(self.obs_dim, self.act_dim, &self.hidden)
    }
}

impl BackendFactory for NativeFactory {
    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.act_dim
    }

    fn ppo_param_count(&self) -> usize {
        self.layout().total()
    }

    fn init_ppo_params(&self, seed: u64) -> Vec<f32> {
        self.layout().init_flat(&mut Pcg64::new(seed))
    }

    fn init_ddpg_params(&self, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let a = actor_layout(self.obs_dim, self.act_dim, &self.hidden).init_flat(&mut rng);
        let c = critic_layout(self.obs_dim, self.act_dim, &self.hidden).init_flat(&mut rng);
        (a, c)
    }

    fn make_actor(&self) -> anyhow::Result<Box<dyn ActorBackend>> {
        Ok(Box::new(NativeActor {
            layout: self.layout(),
            shape: self.shape(),
            batch: 0,
        }))
    }

    fn make_actor_batched(&self, batch: usize) -> anyhow::Result<Box<dyn ActorBackend>> {
        anyhow::ensure!(batch > 0, "make_actor_batched: batch must be >= 1");
        // native kernels are shape-agnostic, so "aligning" the backend is
        // free: the actor simply advertises (and enforces) the exact row
        // count, and the sampler never zero-pads — including batch == 1.
        Ok(Box::new(NativeActor {
            layout: self.layout(),
            shape: self.shape(),
            batch,
        }))
    }

    fn make_actor_shared(&self, max_rows: usize) -> anyhow::Result<Box<dyn ActorBackend>> {
        anyhow::ensure!(max_rows > 0, "make_actor_shared: max_rows must be >= 1");
        // native kernels accept any row count, so the inference server's
        // fleet actor is simply a flexible (batch = 0) actor: every
        // dispatch — full or straggler-cut partial — runs padding-free.
        self.make_actor()
    }

    fn make_ddpg_actor_shared(
        &self,
        max_rows: usize,
    ) -> anyhow::Result<Box<dyn DdpgActorBackend>> {
        anyhow::ensure!(max_rows > 0, "make_ddpg_actor_shared: max_rows must be >= 1");
        self.make_ddpg_actor()
    }

    fn make_ppo_learner(&self) -> anyhow::Result<Box<dyn PpoLearnerBackend>> {
        Ok(Box::new(NativePpoLearner {
            layout: self.layout(),
            shape: self.shape(),
            loss_cfg: PpoLossCfg {
                clip: self.ppo.clip,
                ent_coef: self.ppo.ent_coef,
                vf_coef: self.ppo.vf_coef,
            },
            gamma: self.ppo.gamma,
            lam: self.ppo.lam,
            adam: AdamCfg::default(),
        }))
    }

    fn make_ddpg_actor(&self) -> anyhow::Result<Box<dyn DdpgActorBackend>> {
        Ok(Box::new(NativeDdpgActor {
            layout: actor_layout(self.obs_dim, self.act_dim, &self.hidden),
            shape: self.shape(),
            batch: 0,
        }))
    }

    fn make_ddpg_actor_batched(
        &self,
        batch: usize,
    ) -> anyhow::Result<Box<dyn DdpgActorBackend>> {
        anyhow::ensure!(batch > 0, "make_ddpg_actor_batched: batch must be >= 1");
        Ok(Box::new(NativeDdpgActor {
            layout: actor_layout(self.obs_dim, self.act_dim, &self.hidden),
            shape: self.shape(),
            batch,
        }))
    }

    fn make_ddpg_learner(&self) -> anyhow::Result<Box<dyn DdpgLearnerBackend>> {
        Ok(Box::new(NativeDdpgLearner {
            alayout: actor_layout(self.obs_dim, self.act_dim, &self.hidden),
            clayout: critic_layout(self.obs_dim, self.act_dim, &self.hidden),
            shape: self.shape(),
            gamma: self.ddpg.gamma,
            tau: self.ddpg.tau,
            adam: AdamCfg::default(),
        }))
    }

    fn make_sac_actor(&self, rows: usize) -> anyhow::Result<Box<dyn ActorBackend>> {
        anyhow::ensure!(rows > 0, "make_sac_actor: rows must be >= 1");
        // flexible like every native actor: `rows` is only a sizing hint
        Ok(Box::new(NativeSacActor {
            layout: actor_layout(self.obs_dim, 2 * self.act_dim, &self.hidden),
            shape: self.shape(),
        }))
    }

    fn init_sac_params(&self, seed: u64) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut rng = Pcg64::new(seed);
        let a = actor_layout(self.obs_dim, 2 * self.act_dim, &self.hidden).init_flat(&mut rng);
        let c1 = critic_layout(self.obs_dim, self.act_dim, &self.hidden).init_flat(&mut rng);
        let c2 = critic_layout(self.obs_dim, self.act_dim, &self.hidden).init_flat(&mut rng);
        Ok((a, c1, c2))
    }
}

// ---------------------------------------------------------------- actor

struct NativeActor {
    layout: ParamLayout,
    shape: NetShape,
    /// Exact rows per call when > 0 (batched sampler path); 0 = any.
    batch: usize,
}

impl ActorBackend for NativeActor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn obs_dim(&self) -> usize {
        self.shape.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.shape.act_dim
    }

    fn act(&mut self, flat: &[f32], obs: &[f32], noise: &[f32]) -> anyhow::Result<ActResult> {
        let o = self.shape.obs_dim;
        let a = self.shape.act_dim;
        let b = obs.len() / o;
        anyhow::ensure!(obs.len() == b * o && noise.len() == b * a, "bad act shapes");
        anyhow::ensure!(
            self.batch == 0 || b == self.batch,
            "act: got {b} rows, batched actor expects exactly {}",
            self.batch
        );
        let obs_m = Mat::from_vec(b, o, obs.to_vec());
        let noise_m = Mat::from_vec(b, a, noise.to_vec());
        let out = mlp::act(&self.layout, flat, &self.shape, &obs_m, &noise_m);
        Ok(ActResult {
            action: out.action.data,
            logp: out.logp,
            value: out.value,
            mean: out.mean.data,
        })
    }
}

// --------------------------------------------------------------- learner

struct NativePpoLearner {
    layout: ParamLayout,
    shape: NetShape,
    loss_cfg: PpoLossCfg,
    gamma: f32,
    lam: f32,
    adam: AdamCfg,
}

impl NativePpoLearner {
    fn to_batch(&self, mb: &PpoMinibatch<'_>) -> PpoBatch {
        let o = self.shape.obs_dim;
        let a = self.shape.act_dim;
        let b = mb.old_logp.len();
        PpoBatch {
            obs: Mat::from_vec(b, o, mb.obs.to_vec()),
            act: Mat::from_vec(b, a, mb.act.to_vec()),
            old_logp: mb.old_logp.to_vec(),
            adv: mb.adv.to_vec(),
            ret: mb.ret.to_vec(),
            mask: mb.mask.to_vec(),
        }
    }

    fn adam_for(&self, state: &PpoTrainState) -> Adam {
        Adam {
            cfg: self.adam,
            m: state.m.clone(),
            v: state.v.clone(),
            t: state.t,
        }
    }
}

impl PpoLearnerBackend for NativePpoLearner {
    fn minibatch_size(&self) -> usize {
        0 // any
    }

    fn train_step(
        &mut self,
        state: &mut PpoTrainState,
        lr: f32,
        mb: &PpoMinibatch<'_>,
    ) -> anyhow::Result<PpoStats> {
        let batch = self.to_batch(mb);
        let (grad, stats) =
            mlp::ppo_loss_grad(&self.layout, &state.flat, &self.shape, &batch, &self.loss_cfg);
        let mut adam = self.adam_for(state);
        adam.step(&mut state.flat, &grad, lr);
        state.m = adam.m;
        state.v = adam.v;
        state.t = adam.t;
        Ok(stats)
    }

    fn grad(
        &mut self,
        flat: &[f32],
        mb: &PpoMinibatch<'_>,
    ) -> anyhow::Result<(Vec<f32>, f32, f32)> {
        let batch = self.to_batch(mb);
        let (grad, stats) =
            mlp::ppo_loss_grad(&self.layout, flat, &self.shape, &batch, &self.loss_cfg);
        let n: f32 = mb.mask.iter().sum();
        Ok((grad, stats.total, n))
    }

    fn apply_grads(
        &mut self,
        state: &mut PpoTrainState,
        grads: &[f32],
        lr: f32,
    ) -> anyhow::Result<()> {
        let mut adam = self.adam_for(state);
        adam.step(&mut state.flat, grads, lr);
        state.m = adam.m;
        state.v = adam.v;
        state.t = adam.t;
        Ok(())
    }

    fn gae(
        &mut self,
        rew: &[f32],
        val: &[f32],
        cont: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        Ok(gae_mod::gae(rew, val, cont, self.gamma, self.lam))
    }
}

// ----------------------------------------------------------------- DDPG

struct NativeDdpgActor {
    layout: ParamLayout,
    shape: NetShape,
    /// Exact rows per call when > 0 (batched sampler path); 0 = any.
    batch: usize,
}

impl DdpgActorBackend for NativeDdpgActor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn act(&mut self, actor: &[f32], obs: &[f32]) -> anyhow::Result<Vec<f32>> {
        let o = self.shape.obs_dim;
        let b = obs.len() / o;
        anyhow::ensure!(
            self.batch == 0 || b == self.batch,
            "ddpg act: got {b} rows, batched actor expects exactly {}",
            self.batch
        );
        let obs_m = Mat::from_vec(b, o, obs.to_vec());
        Ok(mlp::ddpg_actor(&self.layout, actor, &self.shape, &obs_m).data)
    }
}

struct NativeDdpgLearner {
    alayout: ParamLayout,
    clayout: ParamLayout,
    shape: NetShape,
    gamma: f32,
    tau: f32,
    adam: AdamCfg,
}

impl DdpgLearnerBackend for NativeDdpgLearner {
    fn batch_size(&self) -> usize {
        0
    }

    fn train_step(
        &mut self,
        st: &mut DdpgTrainState,
        lr_actor: f32,
        lr_critic: f32,
        batch: &DdpgBatch<'_>,
    ) -> anyhow::Result<(f32, f32)> {
        let o = self.shape.obs_dim;
        let a = self.shape.act_dim;
        let b = batch.rew.len();
        let obs = Mat::from_vec(b, o, batch.obs.to_vec());
        let act = Mat::from_vec(b, a, batch.act.to_vec());
        let next_obs = Mat::from_vec(b, o, batch.next_obs.to_vec());

        // TD target from target nets
        let next_a = mlp::ddpg_actor(&self.alayout, &st.targ_actor, &self.shape, &next_obs);
        let q_next = mlp::ddpg_critic(&self.clayout, &st.targ_critic, &self.shape, &next_obs, &next_a);
        let target: Vec<f32> = (0..b)
            .map(|i| batch.rew[i] + self.gamma * (1.0 - batch.done[i]) * q_next[i])
            .collect();

        st.t += 1;
        // critic step
        let (cgrad, q_loss) =
            mlp::ddpg_critic_grad(&self.clayout, &st.critic, &self.shape, &obs, &act, &target);
        let mut cadam = Adam {
            cfg: self.adam,
            m: st.cm.clone(),
            v: st.cv.clone(),
            t: st.t - 1,
        };
        cadam.step(&mut st.critic, &cgrad, lr_critic);
        st.cm = cadam.m;
        st.cv = cadam.v;

        // actor step (through the updated critic, matching model.py)
        let (agrad, pi_loss) = mlp::ddpg_actor_grad(
            &self.alayout,
            &st.actor,
            &self.clayout,
            &st.critic,
            &self.shape,
            &obs,
        );
        let mut aadam = Adam {
            cfg: self.adam,
            m: st.am.clone(),
            v: st.av.clone(),
            t: st.t - 1,
        };
        aadam.step(&mut st.actor, &agrad, lr_actor);
        st.am = aadam.m;
        st.av = aadam.v;

        // Polyak soft target update
        for i in 0..st.actor.len() {
            st.targ_actor[i] = (1.0 - self.tau) * st.targ_actor[i] + self.tau * st.actor[i];
        }
        for i in 0..st.critic.len() {
            st.targ_critic[i] = (1.0 - self.tau) * st.targ_critic[i] + self.tau * st.critic[i];
        }
        Ok((q_loss, pi_loss))
    }
}

// ------------------------------------------------------------------ SAC

/// Tanh-Gaussian SAC actor over `actor_layout(obs, 2*act, hidden)`: the
/// noise lane carries the caller's reparameterization draws eps ~ N(0,1)
/// (`a = tanh(mean + std * eps)`); an all-zero lane therefore yields the
/// squashed mode, which is also returned in `mean` for eval. `value` is
/// zero-filled — SAC's critics live in the learner, not the actor.
struct NativeSacActor {
    layout: ParamLayout,
    shape: NetShape,
}

impl ActorBackend for NativeSacActor {
    fn batch(&self) -> usize {
        0 // any row count
    }

    fn obs_dim(&self) -> usize {
        self.shape.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.shape.act_dim
    }

    fn act(&mut self, flat: &[f32], obs: &[f32], noise: &[f32]) -> anyhow::Result<ActResult> {
        let o = self.shape.obs_dim;
        let a = self.shape.act_dim;
        let b = obs.len() / o;
        anyhow::ensure!(
            obs.len() == b * o && (noise.is_empty() || noise.len() == b * a),
            "bad sac act shapes"
        );
        let obs_m = Mat::from_vec(b, o, obs.to_vec());
        let out = mlp::sac_act(&self.layout, flat, &self.shape, &obs_m, noise);
        Ok(ActResult {
            action: out.action.data,
            logp: out.logp,
            value: vec![0.0; b],
            mean: out.mean_action.data,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn factory() -> NativeFactory {
        NativeFactory::new(3, 2, &[16, 16], PpoCfg::default(), DdpgCfg::default())
    }

    #[test]
    fn actor_shapes_and_determinism() {
        let f = factory();
        let flat = f.init_ppo_params(0);
        let mut actor = f.make_actor().unwrap();
        let obs = vec![0.1f32; 4 * 3];
        let noise = vec![0.0f32; 4 * 2];
        let r1 = actor.act(&flat, &obs, &noise).unwrap();
        let r2 = actor.act(&flat, &obs, &noise).unwrap();
        assert_eq!(r1.action, r2.action);
        assert_eq!(r1.action.len(), 8);
        assert_eq!(r1.logp.len(), 4);
        assert_eq!(r1.action, r1.mean); // zero noise
    }

    #[test]
    fn batched_actor_enforces_exact_rows_and_matches_flexible() {
        let f = factory();
        let flat = f.init_ppo_params(0);
        let mut any = f.make_actor().unwrap();
        let mut four = f.make_actor_batched(4).unwrap();
        assert_eq!(any.batch(), 0);
        assert_eq!(four.batch(), 4);
        let obs = vec![0.3f32; 4 * 3];
        let noise = vec![0.0f32; 4 * 2];
        let ra = any.act(&flat, &obs, &noise).unwrap();
        let rb = four.act(&flat, &obs, &noise).unwrap();
        assert_eq!(ra.action, rb.action);
        assert_eq!(ra.value, rb.value);
        // wrong row count is a hard error, not silent padding
        assert!(four.act(&flat, &obs[..3], &noise[..2]).is_err());
        assert!(f.make_actor_batched(0).is_err());

        let mut d1 = f.make_ddpg_actor_batched(1).unwrap();
        assert_eq!(d1.batch(), 1);
        let (a, _) = f.init_ddpg_params(1);
        assert_eq!(d1.act(&a, &[0.1, 0.2, 0.3]).unwrap().len(), 2);
        assert!(d1.act(&a, &obs).is_err());
    }

    #[test]
    fn shared_actor_accepts_any_row_count() {
        let f = factory();
        let flat = f.init_ppo_params(0);
        let mut shared = f.make_actor_shared(8).unwrap();
        assert_eq!(shared.batch(), 0, "native shared actor must be flexible");
        for b in [1usize, 3, 8] {
            let obs = vec![0.2f32; b * 3];
            let noise = vec![0.0f32; b * 2];
            assert_eq!(shared.act(&flat, &obs, &noise).unwrap().logp.len(), b);
        }
        assert!(f.make_actor_shared(0).is_err());
        assert!(f.make_ddpg_actor_shared(0).is_err());
        assert_eq!(f.make_ddpg_actor_shared(4).unwrap().batch(), 0);
    }

    #[test]
    fn train_step_mutates_state_and_advances_t() {
        let f = factory();
        let mut learner = f.make_ppo_learner().unwrap();
        let mut st = PpoTrainState::new(f.init_ppo_params(1));
        let before = st.flat.clone();
        let b = 16;
        let mut rng = Pcg64::new(2);
        let obs: Vec<f32> = (0..b * 3).map(|_| rng.normal()).collect();
        let act: Vec<f32> = (0..b * 2).map(|_| rng.normal()).collect();
        let old_logp = vec![-2.0f32; b];
        let adv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let ret: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let mask = vec![1.0f32; b];
        let mb = PpoMinibatch {
            obs: &obs,
            act: &act,
            old_logp: &old_logp,
            adv: &adv,
            ret: &ret,
            mask: &mask,
        };
        let stats = learner.train_step(&mut st, 1e-3, &mb).unwrap();
        assert!(stats.total.is_finite());
        assert_eq!(st.t, 1);
        assert_ne!(st.flat, before);
    }

    #[test]
    fn grad_then_apply_equals_train_step() {
        let f = factory();
        let mut l1 = f.make_ppo_learner().unwrap();
        let mut l2 = f.make_ppo_learner().unwrap();
        let flat = f.init_ppo_params(3);
        let mut s1 = PpoTrainState::new(flat.clone());
        let mut s2 = PpoTrainState::new(flat);
        let b = 8;
        let mut rng = Pcg64::new(4);
        let obs: Vec<f32> = (0..b * 3).map(|_| rng.normal()).collect();
        let act: Vec<f32> = (0..b * 2).map(|_| rng.normal()).collect();
        let old_logp = vec![-2.5f32; b];
        let adv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let ret = vec![0.0f32; b];
        let mask = vec![1.0f32; b];
        let mb = PpoMinibatch {
            obs: &obs,
            act: &act,
            old_logp: &old_logp,
            adv: &adv,
            ret: &ret,
            mask: &mask,
        };
        l1.train_step(&mut s1, 1e-3, &mb).unwrap();
        let (g, _, _) = l2.grad(&s2.flat, &mb).unwrap();
        l2.apply_grads(&mut s2, &g, 1e-3).unwrap();
        let max_diff = s1
            .flat
            .iter()
            .zip(&s2.flat)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-6, "{max_diff}");
    }

    #[test]
    fn sac_actor_squashes_and_zero_noise_is_mode() {
        let f = factory();
        let (a, c1, c2) = f.init_sac_params(9).unwrap();
        assert_eq!(a.len(), actor_layout(3, 4, &[16, 16]).total());
        assert_eq!(c1.len(), critic_layout(3, 2, &[16, 16]).total());
        assert_ne!(c1, c2, "twin critics must start from different draws");

        let mut actor = f.make_sac_actor(4).unwrap();
        assert_eq!(actor.batch(), 0, "native SAC actor must be flexible");
        let obs = vec![0.2f32; 4 * 3];
        let zero = vec![0.0f32; 4 * 2];
        let r = actor.act(&a, &obs, &zero).unwrap();
        assert_eq!(r.action.len(), 8);
        assert_eq!(r.logp.len(), 4);
        assert_eq!(r.value, vec![0.0; 4]);
        assert_eq!(r.action, r.mean, "zero eps must yield the squashed mode");
        assert!(r.action.iter().all(|x| x.abs() <= 1.0), "tanh-squashed");

        let mut rng = Pcg64::new(1);
        let mut eps = vec![0.0f32; 4 * 2];
        rng.fill_normal(&mut eps);
        let rs = actor.act(&a, &obs, &eps).unwrap();
        assert_ne!(rs.action, rs.mean, "non-zero eps must perturb the mode");
        assert!(f.make_sac_actor(0).is_err());
    }

    #[test]
    fn ddpg_train_step_moves_targets_toward_online() {
        let f = factory();
        let mut learner = f.make_ddpg_learner().unwrap();
        let (a, c) = f.init_ddpg_params(5);
        let mut st = DdpgTrainState::new(a, c);
        let b = 8;
        let mut rng = Pcg64::new(6);
        let obs: Vec<f32> = (0..b * 3).map(|_| rng.normal()).collect();
        let act: Vec<f32> = (0..b * 2).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let rew = vec![1.0f32; b];
        let next_obs: Vec<f32> = (0..b * 3).map(|_| rng.normal()).collect();
        let done = vec![0.0f32; b];
        let batch = DdpgBatch {
            obs: &obs,
            act: &act,
            rew: &rew,
            next_obs: &next_obs,
            done: &done,
        };
        let ta_before = st.targ_actor.clone();
        let (q_loss, pi_loss) = learner.train_step(&mut st, 1e-3, 1e-3, &batch).unwrap();
        assert!(q_loss.is_finite() && pi_loss.is_finite());
        assert_ne!(st.targ_actor, ta_before);
        // targets moved only a little (tau = 0.005)
        let drift: f32 = st
            .targ_actor
            .iter()
            .zip(&ta_before)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(drift < 0.01, "target drift {drift}");
    }
}
