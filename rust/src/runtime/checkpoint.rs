//! Durable training checkpoints: periodic snapshots an interrupted run
//! resumes from (`--checkpoint-every N` / `--resume <dir>`).
//!
//! A checkpoint captures everything the fleet needs to continue a run as
//! if it had never stopped: the learner's full training state
//! ([`crate::algo::api::LearnerDriver::save_state`] — parameters,
//! optimizer moments, update RNG, normalizer, counters), one opaque
//! snapshot blob per sampler worker (env dynamics + exploration RNG
//! cursors + progress counters, serialized by the coordinator's
//! supervisor), the policy-store version the snapshot was taken at, and
//! a [`RunFingerprint`] so resume refuses checkpoints from a different
//! topology.
//!
//! The orchestrator writes checkpoints at iteration boundaries — the
//! sync-mode barrier where every worker has adopted the just-published
//! version and all chunk buffers are empty, which is what makes the
//! snapshot clean (no half-built chunks to persist). In sync mode a
//! kill-then-resume run reproduces the exact per-env chunk streams of an
//! uninterrupted run, bitwise.
//!
//! ## File format
//!
//! One file per snapshot, `ckpt-{iteration:06}.bin`, written atomically
//! (`.tmp` + rename) so a crash mid-write never corrupts the latest
//! durable snapshot. Little-endian layout via [`crate::util::bytes`]:
//! magic, format version, fingerprint, iteration, store version, learner
//! blob, worker-blob count, worker blobs. Readers reject wrong magic,
//! unknown format versions, and truncated files. Since format v2 the
//! off-policy learner blobs carry the full replay-buffer contents (see
//! [`FORMAT_VERSION`]), so kill-then-resume replays the exact minibatch
//! sequence of an uninterrupted run.

use crate::util::bytes::{ByteReader, ByteWriter};
use anyhow::{Context, Result};
use std::fs;
use std::path::{Path, PathBuf};

/// First 4 bytes of every checkpoint file ("WALL-E checkpoint").
const MAGIC: u32 = 0x57A1_1ECB;
/// Bumped on any incompatible layout change; readers reject mismatches.
///
/// v2: off-policy learner blobs embed the replay buffer *contents* (the
/// versioned `replay::shard` section + the [`ReplayRng`] draw cursor)
/// instead of a bare ring cursor, so a resumed DDPG/TD3/SAC run replays
/// bitwise-identical minibatches. The outer layout is unchanged — the
/// learner blob is opaque here — but v1 blobs are not readable by the
/// new learners, so the version gates them out.
///
/// [`ReplayRng`]: crate::replay::shard::ReplayRng
const FORMAT_VERSION: u32 = 2;

/// Identity of the run a checkpoint belongs to. Resume validates it
/// against the live config: restoring per-worker RNG cursors under a
/// different topology or seed would silently produce garbage streams, so
/// a mismatch is a hard error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunFingerprint {
    /// Environment name (`"pendulum"`, ...).
    pub env: String,
    /// Algorithm name (`"ppo"`, `"ddpg"`, `"td3"`, `"sac"`).
    pub algo: String,
    /// Sampler worker count N.
    pub samplers: usize,
    /// Lockstep envs per worker M.
    pub envs_per_sampler: usize,
    /// Run seed (every RNG stream derives from it).
    pub seed: u64,
}

impl RunFingerprint {
    pub(crate) fn write(&self, w: &mut ByteWriter) {
        w.put_str(&self.env);
        w.put_str(&self.algo);
        w.put_usize(self.samplers);
        w.put_usize(self.envs_per_sampler);
        w.put_u64(self.seed);
    }

    pub(crate) fn read(r: &mut ByteReader<'_>) -> Result<RunFingerprint> {
        Ok(RunFingerprint {
            env: r.read_str()?,
            algo: r.read_str()?,
            samplers: r.read_usize()?,
            envs_per_sampler: r.read_usize()?,
            seed: r.read_u64()?,
        })
    }
}

/// One durable training snapshot (see the module docs for semantics and
/// the on-disk layout).
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Run identity; resume refuses a mismatch.
    pub fingerprint: RunFingerprint,
    /// Training iterations completed when the snapshot was taken; resume
    /// continues at this iteration index.
    pub iteration: u64,
    /// Policy-store version at the snapshot barrier. Resume re-seats the
    /// store so the next publish lands at exactly this version, keeping
    /// chunk `policy_version` labels bitwise-stable across the restart.
    pub version: u64,
    /// Learner training state ([`crate::algo::api::LearnerDriver::save_state`]).
    pub learner: Vec<u8>,
    /// Per-worker snapshot blobs, indexed by worker id (serialized
    /// `coordinator::supervisor::WorkerSnapshot`s — opaque here so the
    /// file format doesn't depend on coordinator internals).
    pub workers: Vec<Vec<u8>>,
}

impl Checkpoint {
    /// Serialize to the on-disk byte layout.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u32(MAGIC);
        w.put_u32(FORMAT_VERSION);
        self.fingerprint.write(&mut w);
        w.put_u64(self.iteration);
        w.put_u64(self.version);
        w.put_bytes(&self.learner);
        w.put_usize(self.workers.len());
        for blob in &self.workers {
            w.put_bytes(blob);
        }
        w.into_vec()
    }

    /// Parse the on-disk byte layout, rejecting wrong magic, unknown
    /// format versions, and truncation.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let mut r = ByteReader::new(bytes);
        let magic = r.read_u32()?;
        anyhow::ensure!(magic == MAGIC, "not a checkpoint file (magic {magic:#x})");
        let version = r.read_u32()?;
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "unsupported checkpoint format version {version} (this build reads {FORMAT_VERSION})"
        );
        let fingerprint = RunFingerprint::read(&mut r)?;
        let iteration = r.read_u64()?;
        let store_version = r.read_u64()?;
        let learner = r.read_bytes()?;
        let n = r.read_usize()?;
        anyhow::ensure!(
            n <= r.remaining(),
            "checkpoint claims {n} worker blobs but only {} bytes remain",
            r.remaining()
        );
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            workers.push(r.read_bytes()?);
        }
        Ok(Checkpoint {
            fingerprint,
            iteration,
            version: store_version,
            learner,
            workers,
        })
    }

    /// Write `ckpt-{iteration:06}.bin` into `dir` atomically (temp file +
    /// rename, so readers never observe a half-written snapshot) and
    /// return the final path. Creates `dir` if missing.
    pub fn write_to(&self, dir: &Path) -> Result<PathBuf> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let name = format!("ckpt-{:06}.bin", self.iteration);
        let tmp = dir.join(format!(".{name}.tmp"));
        let path = dir.join(&name);
        fs::write(&tmp, self.to_bytes())
            .with_context(|| format!("writing checkpoint {}", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("publishing checkpoint {}", path.display()))?;
        Ok(path)
    }
}

/// Load the newest checkpoint (highest iteration number) in `dir`.
/// Errors when the directory has no `ckpt-*.bin` files or the newest one
/// fails to parse — a corrupt latest snapshot should abort resume loudly,
/// not silently fall back to older state.
pub fn load_latest(dir: &Path) -> Result<Checkpoint> {
    let mut best: Option<(u64, PathBuf)> = None;
    let entries = fs::read_dir(dir)
        .with_context(|| format!("reading checkpoint dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        let Some(iter) = name
            .strip_prefix("ckpt-")
            .and_then(|s| s.strip_suffix(".bin"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        let newer = match &best {
            Some((b, _)) => iter > *b,
            None => true,
        };
        if newer {
            best = Some((iter, path));
        }
    }
    let (_, path) =
        best.ok_or_else(|| anyhow::anyhow!("no ckpt-*.bin files in {}", dir.display()))?;
    let bytes =
        fs::read(&path).with_context(|| format!("reading checkpoint {}", path.display()))?;
    Checkpoint::from_bytes(&bytes)
        .with_context(|| format!("parsing checkpoint {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(iter: u64) -> Checkpoint {
        Checkpoint {
            fingerprint: RunFingerprint {
                env: "pendulum".into(),
                algo: "ppo".into(),
                samplers: 4,
                envs_per_sampler: 2,
                seed: 29,
            },
            iteration: iter,
            version: iter + 1,
            learner: vec![1, 2, 3, 4, 5],
            workers: vec![vec![9, 8], vec![], vec![7]],
        }
    }

    #[test]
    fn bytes_round_trip_is_identity() {
        let c = sample(12);
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn wrong_magic_and_truncation_rejected() {
        let mut bytes = sample(3).to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        bytes[0] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn unknown_format_version_rejected() {
        let mut bytes = sample(3).to_bytes();
        bytes[4] = 0xEE; // format-version field follows the magic
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn write_then_load_latest_picks_highest_iteration() {
        let dir = std::env::temp_dir().join("walle_ckpt_test");
        let _ = fs::remove_dir_all(&dir);
        for iter in [2u64, 10, 7] {
            sample(iter).write_to(&dir).unwrap();
        }
        // stray files and half-written temps are ignored
        fs::write(dir.join("notes.txt"), b"x").unwrap();
        fs::write(dir.join(".ckpt-000099.bin.tmp"), b"partial").unwrap();
        let latest = load_latest(&dir).unwrap();
        assert_eq!(latest.iteration, 10);
        assert_eq!(latest, sample(10));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_dir_is_an_error() {
        let dir = std::env::temp_dir().join("walle_ckpt_empty_test");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        assert!(load_latest(&dir).is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
