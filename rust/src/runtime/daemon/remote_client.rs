//! Client side of the policy daemon: an actor handle that speaks the
//! [`wire`] protocol but hands the sampler hot loop the exact same
//! [`ActResponse`] type the in-process [`ActorClient`] does — so
//! `run_algo_sampler` runs unmodified in a separate OS process and the
//! transport stays a pure topology knob (the bitwise-parity contract).
//!
//! One socket, two roles: the hot loop alternates act-request /
//! act-response on the read side, while a forwarder thread pushes
//! finished experience chunks through the same stream (whole-frame
//! writes serialized by [`RemoteActorClient::writer`]'s mutex). The
//! daemon never sends unsolicited frames on an actor connection, so the
//! hot loop owns the read side outright — no demultiplexer needed.
//!
//! [`ActorClient`]: crate::runtime::inference_server::ActorClient

use crate::coordinator::policy_store::PolicySnapshot;
use crate::runtime::checkpoint::RunFingerprint;
use crate::runtime::daemon::wire::{self, Frame, PeerKind, ReadOutcome};
use crate::runtime::inference_server::{ActResponse, ResponseDepot};
use crate::util::plock;
use anyhow::{bail, Context, Result};
use std::os::unix::net::UnixStream;
use std::path::Path;
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How long a blocked socket read sleeps before re-checking the stop
/// flag (mirrors the in-process client's 50ms liveness probe, scaled to
/// the coarser cross-process failure domain).
pub const READ_PROBE: Duration = Duration::from_millis(200);

/// Open a socket to the daemon and run the [`Frame::Hello`] handshake.
/// Returns the stream plus the daemon's current policy version and
/// normalizer snapshot. A [`Frame::HelloErr`] (fingerprint mismatch,
/// busy worker id, protocol skew) becomes an actionable error here —
/// the client-side half of the both-ends rejection contract.
pub fn connect(
    sock: &Path,
    kind: PeerKind,
    fingerprint: &RunFingerprint,
    worker_id: usize,
    m: usize,
    stop: &AtomicBool,
) -> Result<(UnixStream, u64, crate::algo::normalizer::NormSnapshot)> {
    let mut stream = UnixStream::connect(sock)
        .with_context(|| format!("connecting to policy daemon at {}", sock.display()))?;
    stream
        .set_read_timeout(Some(READ_PROBE))
        .context("setting socket read timeout")?;
    wire::write_frame(
        &mut stream,
        &Frame::Hello {
            kind,
            fingerprint: fingerprint.clone(),
            worker_id,
            m,
        },
    )
    .context("sending handshake")?;
    match wire::read_frame(&mut stream, stop).context("awaiting handshake reply")? {
        ReadOutcome::Frame(Frame::HelloOk { version, norm }, _) => Ok((stream, version, norm)),
        ReadOutcome::Frame(Frame::HelloErr { message }, _) => {
            bail!("daemon at {} rejected the handshake: {message}", sock.display())
        }
        ReadOutcome::Frame(f, _) => bail!("expected HelloOk, daemon sent {}", f.kind_name()),
        ReadOutcome::Eof => bail!(
            "daemon at {} closed the socket during the handshake",
            sock.display()
        ),
    }
}

/// Remote counterpart of the in-process `ActorClient`: submits one
/// worker's slab per tick over the daemon socket and wraps the reply
/// into a real [`ActResponse`] (drop-recycled through a
/// [`ResponseDepot`]). The cached [`PolicySnapshot`] carries the
/// daemon's version + normalizer with an EMPTY parameter vector — the
/// weights live in the daemon; the hot loop only reads `version`/`norm`
/// off the snapshot on this path.
pub struct RemoteActorClient {
    /// Read side of the socket (exclusive to the hot loop).
    reader: UnixStream,
    /// Write side, shared with the chunk forwarder thread — every frame
    /// goes out whole under this lock.
    writer: Arc<Mutex<UnixStream>>,
    depot: ResponseDepot,
    stop: Arc<AtomicBool>,
    snapshot: Arc<PolicySnapshot>,
    obs_dim: usize,
    act_dim: usize,
}

impl RemoteActorClient {
    /// Connect + handshake as `PeerKind::Actor` for worker `worker_id`
    /// submitting `m`-row slabs.
    pub fn connect(
        sock: &Path,
        fingerprint: &RunFingerprint,
        worker_id: usize,
        m: usize,
        obs_dim: usize,
        act_dim: usize,
        stop: Arc<AtomicBool>,
    ) -> Result<RemoteActorClient> {
        let (stream, version, norm) = connect(
            sock,
            PeerKind::Actor,
            fingerprint,
            worker_id,
            m,
            stop.as_ref(),
        )?;
        let reader = stream.try_clone().context("cloning daemon socket")?;
        Ok(RemoteActorClient {
            reader,
            writer: Arc::new(Mutex::new(stream)),
            depot: ResponseDepot::new(obs_dim, act_dim),
            stop,
            snapshot: Arc::new(PolicySnapshot {
                version,
                params: Arc::new(Vec::new()),
                norm,
                quant: None,
            }),
            obs_dim,
            act_dim,
        })
    }

    /// The shared write handle for the chunk forwarder thread (chunk
    /// pushes interleave with act requests at frame granularity).
    pub fn writer(&self) -> Arc<Mutex<UnixStream>> {
        self.writer.clone()
    }

    /// Submit this worker's slab and block until the daemon's dispatch
    /// answers it — the wire mirror of `ActorClient::act`, same
    /// contract: `noise` holds `rows * act_dim` N(0,1) draws (PPO) or is
    /// empty (DDPG). Noise is drawn CLIENT-side from the worker's own
    /// RNG stream, exactly as in-process, which is what keeps the
    /// per-env trajectories bitwise identical across fleet modes.
    pub fn act(&mut self, raw_obs: &[f32], noise: &[f32]) -> Result<ActResponse> {
        anyhow::ensure!(
            !raw_obs.is_empty() && raw_obs.len() % self.obs_dim == 0,
            "client slab must be a whole number of obs rows"
        );
        let rows = raw_obs.len() / self.obs_dim;
        anyhow::ensure!(
            noise.is_empty() || noise.len() == rows * self.act_dim,
            "noise must be empty (ddpg) or rows * act_dim"
        );
        // encode outside the lock; hold it only for the write so the
        // forwarder can slip chunk frames in while we await the reply
        let req = Frame::ActReq {
            rows,
            obs: raw_obs.to_vec(),
            noise: noise.to_vec(),
        };
        wire::write_frame(&mut *plock(&self.writer), &req).context("sending act request")?;

        let r = match wire::read_frame(&mut self.reader, &self.stop)
            .context("awaiting act response")?
        {
            ReadOutcome::Frame(Frame::ActResp(r), _) => r,
            ReadOutcome::Frame(Frame::ActErr { message }, _) => {
                bail!("daemon failed the act request: {message}")
            }
            ReadOutcome::Frame(f, _) => bail!("expected ActResp, daemon sent {}", f.kind_name()),
            ReadOutcome::Eof => bail!("daemon closed the connection mid-run"),
        };
        anyhow::ensure!(
            r.rows == rows
                && r.action.len() == rows * self.act_dim
                && r.logp.len() == rows
                && r.value.len() == rows
                && r.mean.len() == rows * self.act_dim
                && r.norm_obs.len() == rows * self.obs_dim,
            "act response shape mismatch (daemon sent {} rows for a {rows}-row request)",
            r.rows
        );
        if r.version != self.snapshot.version {
            // first response under a new version carries the snapshot's
            // normalizer; rebuild the cached (param-less) snapshot once
            let norm = match r.norm {
                Some(n) => n,
                None => bail!(
                    "daemon flipped to version {} without shipping its normalizer",
                    r.version
                ),
            };
            self.snapshot = Arc::new(PolicySnapshot {
                version: r.version,
                params: Arc::new(Vec::new()),
                norm,
                quant: None,
            });
        }
        // move the decoded lanes into a recycled buffer set; obs carries
        // the server-side normalized rows, exactly like the local path
        let mut bufs = self.depot.buffers();
        bufs.obs = r.norm_obs;
        bufs.noise.clear();
        bufs.action = r.action;
        bufs.logp = r.logp;
        bufs.value = r.value;
        bufs.mean = r.mean;
        Ok(self
            .depot
            .response(bufs, rows, self.snapshot.clone(), r.epoch, r.server_busy_secs))
    }
}
