//! Policy daemon: the InferencePool promoted to a standalone
//! multi-process serving tier.
//!
//! The daemon owns the shared [`InferencePool`] + [`PolicyStore`] +
//! experience queue and serves N remote sampler processes over a
//! Unix-domain socket speaking the [`wire`] frame protocol. Each child
//! process runs the UNMODIFIED `run_algo_sampler` hot loop against a
//! [`remote_client::RemoteActorClient`] — the transport is a pure
//! topology knob: because the MLP forward is row-independent and noise
//! is drawn client-side from the worker's own RNG streams, per-(worker,
//! env_slot) chunk streams are bitwise identical between
//! `--fleet-mode threads` and `--fleet-mode procs`.
//!
//! Three connection roles per child:
//! * **Actor** (`PeerKind::Actor`) — the hot loop's act-request /
//!   act-response ping-pong, plus experience-chunk pushes interleaved by
//!   the child's forwarder thread. The daemon pre-registers one
//!   [`ActorClient`] per worker id BEFORE its serve threads start (so no
//!   shard ever observes an empty fleet) and stashes it between
//!   connections — a respawned child re-claims its slot.
//! * **Subscriber** (`PeerKind::Subscriber`) — a version long-poll: the
//!   child sends `WaitNewer{seen}` and the daemon answers with the next
//!   published version + normalizer, which the child mirrors into its
//!   LOCAL [`PolicyStore`] so the sampler's sync-mode budget stalls
//!   resolve exactly as they do in threads mode.
//!
//! Every connection handshakes with the run's [`RunFingerprint`] (env,
//! algorithm, fleet shape, seed); a mismatch is rejected with an
//! actionable message on BOTH ends — serving a client from a different
//! run identity would silently corrupt every RNG stream.

pub mod remote_client;
pub mod wire;

use crate::algo::api::{algorithm_from_config, Algorithm, LearnerDriver};
use crate::algo::rollout::ExperienceChunk;
use crate::config::{InferEpoch, InferWait, TrainConfig};
use crate::coordinator::metrics::{Histogram, InferenceReport, WIRE_FRAME_BYTE_BOUNDS};
use crate::coordinator::policy_store::{PolicySnapshot, PolicyStore};
use crate::coordinator::queue::Channel;
use crate::coordinator::sampler::{run_algo_sampler_supervised, PolicySource, SamplerCfg};
use crate::env::vec_env::VecEnv;
use crate::runtime::checkpoint::{self, RunFingerprint};
use crate::runtime::epoch::EpochMode;
use crate::runtime::inference_server::{
    ActorClient, InferencePool, InferencePoolCfg, WaitPolicy,
};
use crate::runtime::BackendFactory;
use crate::util::plock;
use anyhow::{bail, Context, Result};
use remote_client::RemoteActorClient;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;
use wire::{Frame, PeerKind, ReadOutcome};

/// Env var a sampler child reads as a chunk-count kill switch: after
/// forwarding this many chunks the child exits with
/// [`EXIT_AFTER_CHUNKS_CODE`]. A deterministic stand-in for SIGKILL in
/// respawn tests; the orchestrator strips it from respawned children so
/// one scripted death cannot become an infinite death loop.
pub const EXIT_AFTER_CHUNKS_ENV: &str = "WALLE_SAMPLE_EXIT_AFTER_CHUNKS";

/// Exit code of the [`EXIT_AFTER_CHUNKS_ENV`] kill switch (distinct from
/// 0/1 so a reaper test can tell a scripted death from a real failure).
pub const EXIT_AFTER_CHUNKS_CODE: i32 = 101;

/// The identity every daemon connection must present: built from the
/// SAME config fields on both ends, so equality means "this client was
/// launched for this run".
pub fn run_fingerprint(cfg: &TrainConfig) -> RunFingerprint {
    RunFingerprint {
        env: cfg.env.clone(),
        algo: cfg.algo.name().to_string(),
        samplers: cfg.samplers,
        envs_per_sampler: cfg.envs_per_sampler,
        seed: cfg.seed,
    }
}

/// The shared inference pool for a daemon-backed run — identical to the
/// threads-mode construction in the orchestrator (wait policy, epoch
/// gate, flip schedule), so the serving tier changes nothing about
/// dispatch semantics.
pub fn build_pool(cfg: &TrainConfig, factory: &dyn BackendFactory) -> Arc<InferencePool> {
    Arc::new(InferencePool::with_flip_schedule(
        InferencePoolCfg {
            workers: cfg.samplers,
            rows_per_worker: cfg.envs_per_sampler,
            shards: cfg.infer_shards.resolve(cfg.samplers),
            wait: match cfg.infer_wait {
                InferWait::Adaptive => WaitPolicy::Adaptive,
                InferWait::Fixed(us) => WaitPolicy::Fixed(Duration::from_micros(us)),
            },
            epoch: match cfg.infer_epoch {
                InferEpoch::Pool => EpochMode::Pool,
                InferEpoch::Shard => EpochMode::Shard,
            },
            obs_dim: factory.obs_dim(),
            act_dim: factory.act_dim(),
        },
        cfg.flip_schedule,
    ))
}

// ------------------------------------------------------------- metrics

/// Live wire counters for one daemon, merged into the end-of-run
/// [`InferenceReport`] (the `wire traffic:` lines of `fleet health`).
/// Byte counts include the 4-byte length prefixes.
pub struct WireMetrics {
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    handshakes: AtomicU64,
    disconnects: AtomicU64,
    frame_bytes: Mutex<Histogram>,
}

impl WireMetrics {
    pub fn new() -> WireMetrics {
        WireMetrics {
            frames_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            handshakes: AtomicU64::new(0),
            disconnects: AtomicU64::new(0),
            frame_bytes: Mutex::new(Histogram::new(WIRE_FRAME_BYTE_BOUNDS)),
        }
    }

    fn count_in(&self, bytes: usize) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes as u64, Ordering::Relaxed);
        plock(&self.frame_bytes).record(bytes as f64);
    }

    fn count_out(&self, bytes: usize) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
        plock(&self.frame_bytes).record(bytes as f64);
    }

    fn count_handshake(&self) {
        self.handshakes.fetch_add(1, Ordering::Relaxed);
    }

    fn count_disconnect(&self) {
        self.disconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold the live counters into a report (end of run, after every
    /// connection thread has exited).
    pub fn merge_into(&self, rep: &mut InferenceReport) {
        rep.wire_frames_in += self.frames_in.load(Ordering::Relaxed);
        rep.wire_frames_out += self.frames_out.load(Ordering::Relaxed);
        rep.wire_bytes_in += self.bytes_in.load(Ordering::Relaxed);
        rep.wire_bytes_out += self.bytes_out.load(Ordering::Relaxed);
        rep.wire_handshakes += self.handshakes.load(Ordering::Relaxed);
        rep.wire_disconnects += self.disconnects.load(Ordering::Relaxed);
        rep.wire_frame_bytes.merge(&plock(&self.frame_bytes));
    }
}

impl Default for WireMetrics {
    fn default() -> Self {
        Self::new()
    }
}

// ------------------------------------------------------ socket helpers

/// A per-process, per-call unique socket path under the temp dir (the
/// `--fleet-mode procs` default; `walle serve` takes `--socket`).
pub fn default_socket_path() -> PathBuf {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "walle-fleet-{}-{}.sock",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    ))
}

/// The config sidecar written next to the socket (`<sock>.config.json`):
/// sampler children load it so both processes resolve the run from the
/// IDENTICAL config — the fingerprint handshake then only guards against
/// pointing `--connect` at the wrong daemon.
pub fn config_sidecar(sock: &Path) -> PathBuf {
    let mut os = sock.as_os_str().to_os_string();
    os.push(".config.json");
    PathBuf::from(os)
}

/// Bind the daemon listener, unlinking a STALE socket file first (a
/// previous daemon that died without cleanup). A socket something still
/// answers on is a live daemon — refuse to hijack it.
pub fn bind_socket(sock: &Path) -> Result<UnixListener> {
    if sock.exists() {
        match UnixStream::connect(sock) {
            Ok(_) => bail!(
                "{} is already served by a live daemon — stop it first, or pick \
                 a different --socket path",
                sock.display()
            ),
            Err(_) => {
                crate::log_warn!(
                    "removing stale socket {} (no daemon answered)",
                    sock.display()
                );
                std::fs::remove_file(sock)
                    .with_context(|| format!("unlinking stale socket {}", sock.display()))?;
            }
        }
    }
    UnixListener::bind(sock).with_context(|| format!("binding {}", sock.display()))
}

// ------------------------------------------------------- daemon server

/// Everything a daemon connection thread needs. Cheap to clone (Arcs +
/// borrows); one clone per connection.
#[derive(Clone)]
pub struct DaemonCtx<'a> {
    pub fingerprint: RunFingerprint,
    /// Rows per act request (envs per sampler, M).
    pub m: usize,
    pub pool: Arc<InferencePool>,
    pub store: &'a PolicyStore,
    pub queue: &'a Channel<ExperienceChunk>,
    pub stop: &'a AtomicBool,
    /// Pre-registered per-worker [`ActorClient`]s, parked here whenever
    /// the worker's child is not connected. Holding the client IS the
    /// shard keep-alive: a shard's serve loop only exits once every one
    /// of its clients is dropped, which happens when the stash itself is
    /// dropped at shutdown.
    pub stash: Arc<Mutex<Vec<Option<ActorClient>>>>,
    pub metrics: Arc<WireMetrics>,
}

impl<'a> DaemonCtx<'a> {
    /// Build the context, registering one client per worker id with the
    /// pool. MUST run before the pool's serve threads start (the same
    /// pre-registration rule the threads-mode orchestrator follows).
    pub fn new(
        cfg: &TrainConfig,
        pool: Arc<InferencePool>,
        store: &'a PolicyStore,
        queue: &'a Channel<ExperienceChunk>,
        stop: &'a AtomicBool,
    ) -> DaemonCtx<'a> {
        let stash = (0..cfg.samplers).map(|id| Some(pool.client(id))).collect();
        DaemonCtx {
            fingerprint: run_fingerprint(cfg),
            m: cfg.envs_per_sampler,
            pool,
            store,
            queue,
            stop,
            stash: Arc::new(Mutex::new(stash)),
            metrics: Arc::new(WireMetrics::new()),
        }
    }
}

/// Accept-and-serve loop: polls the listener (non-blocking, 50ms) until
/// `ctx.stop` flips or the queue closes, spawning one scoped connection
/// thread per client. Runs on a scoped thread itself; `Scope` is `Sync`,
/// so nested spawns work.
pub fn accept_loop<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    listener: UnixListener,
    ctx: DaemonCtx<'env>,
) {
    if let Err(e) = listener.set_nonblocking(true) {
        crate::log_error!("daemon listener: cannot set non-blocking: {e}");
        return;
    }
    loop {
        if ctx.stop.load(Ordering::Relaxed) || ctx.queue.is_closed() {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let conn_ctx = ctx.clone();
                scope.spawn(move || serve_connection(stream, conn_ctx));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => {
                crate::log_warn!("daemon accept failed: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn serve_connection(mut stream: UnixStream, ctx: DaemonCtx<'_>) {
    if let Err(e) = connection(&mut stream, &ctx) {
        if !ctx.stop.load(Ordering::Relaxed) && !ctx.queue.is_closed() {
            crate::log_warn!("daemon connection ended with an error: {e:#}");
        }
    }
}

/// One connection, handshake to hangup.
fn connection(stream: &mut UnixStream, ctx: &DaemonCtx<'_>) -> Result<()> {
    stream.set_nonblocking(false).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(200)))
        .context("setting connection read timeout")?;
    let hello = match wire::read_frame(stream, ctx.stop)? {
        ReadOutcome::Frame(f, n) => {
            ctx.metrics.count_in(n);
            f
        }
        // connect-then-close is how `bind_socket` probes for a live
        // daemon — not an error
        ReadOutcome::Eof => return Ok(()),
    };
    let (kind, fingerprint, worker_id, m) = match hello {
        Frame::Hello {
            kind,
            fingerprint,
            worker_id,
            m,
        } => (kind, fingerprint, worker_id, m),
        f => bail!("expected Hello, peer sent {}", f.kind_name()),
    };
    if fingerprint != ctx.fingerprint {
        let message = wire::fingerprint_mismatch(&ctx.fingerprint, &fingerprint);
        reject(stream, ctx, &message)?;
        bail!("rejected {kind:?} handshake from worker {worker_id}: {message}");
    }
    if m != ctx.m {
        let message = format!(
            "client submits {m}-row slabs but this daemon serves {} envs per \
             sampler — both ends must run the same config",
            ctx.m
        );
        reject(stream, ctx, &message)?;
        bail!("rejected {kind:?} handshake from worker {worker_id}: {message}");
    }
    // HelloOk always carries a live version: wait out the gap between
    // bind and the first publish
    let snap = match wait_first_snapshot(ctx) {
        Some(s) => s,
        None => return Ok(()), // shut down before the first publish
    };
    match kind {
        PeerKind::Actor => actor_connection(stream, ctx, worker_id, snap),
        PeerKind::Subscriber => subscriber_connection(stream, ctx, snap),
    }
}

fn reject(stream: &mut UnixStream, ctx: &DaemonCtx<'_>, message: &str) -> Result<()> {
    let n = wire::write_frame(
        stream,
        &Frame::HelloErr {
            message: message.to_string(),
        },
    )
    .context("sending handshake rejection")?;
    ctx.metrics.count_out(n);
    Ok(())
}

fn wait_first_snapshot(ctx: &DaemonCtx<'_>) -> Option<Arc<PolicySnapshot>> {
    loop {
        if let Some(s) = ctx.store.latest() {
            return Some(s);
        }
        if ctx.stop.load(Ordering::Relaxed) || ctx.queue.is_closed() {
            return None;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Actor connection: claim the worker's stashed client, serve the
/// act/chunk loop, and park the client back on the way out — so the
/// shard never sees a zero-client window and a respawned child can
/// re-claim the slot.
fn actor_connection(
    stream: &mut UnixStream,
    ctx: &DaemonCtx<'_>,
    worker_id: usize,
    snap: Arc<PolicySnapshot>,
) -> Result<()> {
    if worker_id >= plock(&ctx.stash).len() {
        let message = format!(
            "worker id {worker_id} is out of range for a {}-sampler fleet",
            plock(&ctx.stash).len()
        );
        reject(stream, ctx, &message)?;
        bail!("{message}");
    }
    // Claim the slot, waiting out the respawn race: a respawned child can
    // connect before its dead predecessor's connection thread notices the
    // EOF (one read probe, 200ms) and parks the client back. Only a slot
    // still taken after the grace period is a genuinely duplicate worker.
    let deadline = std::time::Instant::now() + Duration::from_secs(2);
    let mut client = loop {
        if let Some(c) = plock(&ctx.stash)[worker_id].take() {
            break c;
        }
        if ctx.stop.load(Ordering::Relaxed) || ctx.queue.is_closed() {
            return Ok(());
        }
        if std::time::Instant::now() >= deadline {
            let message = format!(
                "worker id {worker_id} is already connected — every sampler \
                 process needs a distinct --worker-id"
            );
            reject(stream, ctx, &message)?;
            bail!("{message}");
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    // a SIGKILLed predecessor may have left a dispatched reply in the
    // slot; drain it so this child's first act sees a clean client
    client.reset_stale();
    let n = wire::write_frame(
        stream,
        &Frame::HelloOk {
            version: snap.version,
            norm: snap.norm.clone(),
        },
    )
    .context("sending HelloOk")?;
    ctx.metrics.count_out(n);
    ctx.metrics.count_handshake();
    let mut last_version = snap.version;
    let res = actor_loop(stream, ctx, &mut client, &mut last_version);
    client.reset_stale();
    plock(&ctx.stash)[worker_id] = Some(client);
    ctx.metrics.count_disconnect();
    res
}

fn actor_loop(
    stream: &mut UnixStream,
    ctx: &DaemonCtx<'_>,
    client: &mut ActorClient,
    last_version: &mut u64,
) -> Result<()> {
    loop {
        let frame = match wire::read_frame(stream, ctx.stop) {
            Ok(ReadOutcome::Frame(f, n)) => {
                ctx.metrics.count_in(n);
                f
            }
            Ok(ReadOutcome::Eof) => return Ok(()),
            Err(e) => {
                if ctx.stop.load(Ordering::Relaxed) {
                    return Ok(()); // shutdown raced the read
                }
                return Err(e);
            }
        };
        match frame {
            Frame::ActReq { rows, obs, noise } => {
                // retry a down shard exactly like the supervised
                // in-process worker does: `act` is retry-safe after Err
                // and the shard supervisor is respawning the serve
                // thread concurrently
                let resp = loop {
                    match client.act(&obs, &noise) {
                        Ok(r) => break Ok(r),
                        Err(e) => {
                            if ctx.stop.load(Ordering::Relaxed) || ctx.queue.is_closed() {
                                break Err(e);
                            }
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                };
                match resp {
                    Ok(r) => {
                        let version = r.snapshot.version;
                        // ship the normalizer only on the first response
                        // under a new version (per connection) — the
                        // client caches it in its param-less snapshot
                        let norm = if version != *last_version {
                            *last_version = version;
                            Some(r.snapshot.norm.clone())
                        } else {
                            None
                        };
                        let out = Frame::ActResp(wire::ActRespWire {
                            version,
                            epoch: r.epoch,
                            server_busy_secs: r.server_busy_secs,
                            rows,
                            action: r.action().to_vec(),
                            logp: r.logp().to_vec(),
                            value: r.value().to_vec(),
                            mean: r.mean().to_vec(),
                            norm_obs: r.norm_obs().to_vec(),
                            norm,
                        });
                        drop(r); // recycle the slab before the write blocks
                        let n =
                            wire::write_frame(stream, &out).context("sending act response")?;
                        ctx.metrics.count_out(n);
                    }
                    Err(e) => {
                        let n = wire::write_frame(
                            stream,
                            &Frame::ActErr {
                                message: format!("{e:#}"),
                            },
                        )
                        .context("sending act error")?;
                        ctx.metrics.count_out(n);
                        return Err(e);
                    }
                }
            }
            Frame::Chunk(chunk) => {
                // blocking push: queue backpressure stalls this
                // connection exactly like it stalls a threads-mode
                // worker. After close (shutdown) the chunk is dropped.
                let _ = ctx.queue.push(*chunk);
            }
            f => bail!("unexpected {} on an actor connection", f.kind_name()),
        }
    }
}

/// Subscriber connection: answer each `WaitNewer{seen}` long-poll with
/// the next published version + normalizer (checking shutdown every
/// 200ms), so the child can mirror the daemon's store locally.
fn subscriber_connection(
    stream: &mut UnixStream,
    ctx: &DaemonCtx<'_>,
    snap: Arc<PolicySnapshot>,
) -> Result<()> {
    let n = wire::write_frame(
        stream,
        &Frame::HelloOk {
            version: snap.version,
            norm: snap.norm.clone(),
        },
    )
    .context("sending HelloOk")?;
    ctx.metrics.count_out(n);
    ctx.metrics.count_handshake();
    loop {
        let frame = match wire::read_frame(stream, ctx.stop) {
            Ok(ReadOutcome::Frame(f, n)) => {
                ctx.metrics.count_in(n);
                f
            }
            Ok(ReadOutcome::Eof) => return Ok(()),
            Err(e) => {
                if ctx.stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                return Err(e);
            }
        };
        match frame {
            Frame::WaitNewer { seen } => {
                let newer = loop {
                    if ctx.stop.load(Ordering::Relaxed) || ctx.queue.is_closed() {
                        // shutdown: hang up instead of answering; the
                        // child's pump treats EOF as its stop signal
                        return Ok(());
                    }
                    if let Some(s) = ctx.store.wait_newer(seen, Duration::from_millis(200)) {
                        break s;
                    }
                };
                let n = wire::write_frame(
                    stream,
                    &Frame::Version {
                        version: newer.version,
                        norm: newer.norm.clone(),
                    },
                )
                .context("sending version push")?;
                ctx.metrics.count_out(n);
            }
            f => bail!("unexpected {} on a subscriber connection", f.kind_name()),
        }
    }
}

// -------------------------------------------------------- sampler child

/// The `walle sample --connect <sock> --worker-id K` process body: run
/// one unmodified sampler hot loop against a remote daemon.
///
/// Three threads: the hot loop (this thread) driving
/// [`PolicySource::Remote`], a chunk forwarder streaming finished
/// chunks back over the actor socket, and a version pump mirroring the
/// daemon's publishes into a LOCAL [`PolicyStore`] (param-less: only
/// version + normalizer travel; the weights live in the daemon). The
/// pump is the sole writer of the local store, so the sampler's
/// sync-mode `wait_newer` stalls resolve on exactly the daemon's
/// publish boundaries — the keystone of threads/procs bitwise parity.
pub fn run_sample_child(
    cfg: &TrainConfig,
    sock: &Path,
    worker_id: usize,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        worker_id < cfg.samplers,
        "--worker-id {worker_id} is out of range for a {}-sampler fleet",
        cfg.samplers
    );
    // process-global modes must match the daemon's before the first
    // forward / env reset (same rule as the threads-mode orchestrator)
    crate::nn::kernels::set_mode(cfg.kernels.mode());
    crate::env::batch::set_engine(cfg.env_engine.engine());
    let factory = crate::runtime::make_factory(cfg)?;
    let algo = algorithm_from_config(cfg);
    let fingerprint = run_fingerprint(cfg);
    let m = cfg.envs_per_sampler;

    // subscriber connection first: seed the local store at the daemon's
    // current version so the hot loop's first wait_newer(0) resolves
    let (sub, v0, n0) = remote_client::connect(
        sock,
        PeerKind::Subscriber,
        &fingerprint,
        worker_id,
        m,
        stop.as_ref(),
    )?;
    let store = PolicyStore::new();
    store.resume_at(v0.saturating_sub(1));
    store.publish(Vec::new(), n0);

    let actor = RemoteActorClient::connect(
        sock,
        &fingerprint,
        worker_id,
        m,
        factory.obs_dim(),
        factory.act_dim(),
        stop.clone(),
    )?;
    let writer = actor.writer();
    let queue: Channel<ExperienceChunk> = Channel::new(cfg.queue_capacity);
    let exit_after: Option<u64> = std::env::var(EXIT_AFTER_CHUNKS_ENV)
        .ok()
        .and_then(|s| s.parse().ok());

    let sync_budget = if cfg.async_mode {
        None
    } else {
        // identical ceil-divide to the orchestrator: both processes must
        // agree on the per-version budget or sync mode deadlocks
        Some((cfg.samples_per_iter + cfg.samplers - 1) / cfg.samplers)
    };
    let scfg = SamplerCfg {
        id: worker_id,
        seed: cfg.seed,
        chunk_steps: cfg.chunk_steps,
        sync_budget,
        reward_scale: cfg.reward_scale,
    };
    let venv = VecEnv::from_registry(&cfg.env, m, cfg.seed, (worker_id * m) as u64 + 1)?;

    let report = std::thread::scope(|s| {
        s.spawn(|| version_pump(sub, &store, &stop));
        s.spawn(|| chunk_forwarder(&queue, &writer, exit_after, &stop));
        let report = run_algo_sampler_supervised(
            algo.as_ref(),
            scfg,
            venv,
            PolicySource::Remote(actor),
            &store,
            &queue,
            &stop,
            None,
        );
        // unblock the pump (read probe) and the forwarder (pop)
        stop.store(true, Ordering::Relaxed);
        queue.close();
        report
    });
    crate::log_info!(
        "sampler child {worker_id}: {} steps, {} chunks delivered",
        report.steps,
        report.chunks
    );
    Ok(())
}

/// Mirror the daemon's publishes into the child's local store. Any link
/// failure flips the child's stop flag — a sampler stalled at a sync
/// budget with a dead pump would otherwise wait forever for a local
/// publish that can never come.
fn version_pump(mut sub: UnixStream, store: &PolicyStore, stop: &AtomicBool) {
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let seen = store.version();
        if wire::write_frame(&mut sub, &Frame::WaitNewer { seen }).is_err() {
            if !stop.swap(true, Ordering::Relaxed) {
                crate::log_warn!("version pump: daemon link lost; stopping this sampler");
            }
            return;
        }
        match wire::read_frame(&mut sub, stop) {
            Ok(ReadOutcome::Frame(Frame::Version { version, norm }, _)) => {
                if version > store.version() {
                    // resume_at(v-1) + publish lands the local store at
                    // exactly the daemon's version
                    store.resume_at(version.saturating_sub(1));
                    store.publish(Vec::new(), norm);
                }
            }
            Ok(ReadOutcome::Frame(f, _)) => {
                crate::log_warn!("version pump: unexpected {}; stopping", f.kind_name());
                stop.store(true, Ordering::Relaxed);
                return;
            }
            Ok(ReadOutcome::Eof) | Err(_) => {
                // clean daemon shutdown or a dead link: either way the
                // run is over for this child
                stop.store(true, Ordering::Relaxed);
                return;
            }
        }
    }
}

/// Stream finished chunks back to the daemon, interleaving whole frames
/// with the hot loop's act requests under the shared write lock.
fn chunk_forwarder(
    queue: &Channel<ExperienceChunk>,
    writer: &Arc<Mutex<UnixStream>>,
    exit_after: Option<u64>,
    stop: &AtomicBool,
) {
    let mut sent = 0u64;
    loop {
        let chunk = match queue.pop() {
            Ok(c) => c,
            Err(_) => return, // closed and drained
        };
        let frame = Frame::Chunk(Box::new(chunk));
        if wire::write_frame(&mut *plock(writer), &frame).is_err() {
            if !stop.swap(true, Ordering::Relaxed) {
                crate::log_warn!("chunk forwarder: daemon link lost; stopping this sampler");
            }
            return;
        }
        sent += 1;
        if exit_after.is_some_and(|k| sent >= k) {
            crate::log_warn!(
                "{EXIT_AFTER_CHUNKS_ENV}={} reached; exiting {EXIT_AFTER_CHUNKS_CODE}",
                exit_after.unwrap()
            );
            std::process::exit(EXIT_AFTER_CHUNKS_CODE);
        }
    }
}

// ------------------------------------------------------ process spawn

/// The `walle` binary to spawn sampler children from: `WALLE_BIN` if set
/// (integration tests point it at the real binary — `current_exe` would
/// resolve to the TEST harness), else this executable.
pub fn walle_binary() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("WALLE_BIN") {
        return Ok(PathBuf::from(p));
    }
    std::env::current_exe().context("resolving the walle binary for sampler children")
}

/// Spawn one `walle sample` child. `inherit_kill_switch = false` strips
/// [`EXIT_AFTER_CHUNKS_ENV`] (respawned incarnations must not re-die on
/// the scripted trigger).
pub fn spawn_sampler(
    bin: &Path,
    sock: &Path,
    config: &Path,
    worker_id: usize,
    inherit_kill_switch: bool,
) -> Result<std::process::Child> {
    let mut cmd = std::process::Command::new(bin);
    cmd.arg("sample")
        .arg("--connect")
        .arg(sock)
        .arg("--config")
        .arg(config)
        .arg("--worker-id")
        .arg(worker_id.to_string());
    if !inherit_kill_switch {
        cmd.env_remove(EXIT_AFTER_CHUNKS_ENV);
    }
    cmd.spawn()
        .with_context(|| format!("spawning sampler child {worker_id} from {}", bin.display()))
}

/// SIGTERM, bounded grace, then SIGKILL — the shutdown path for sampler
/// children still alive when the run ends.
pub fn terminate_child(mut child: std::process::Child, worker_id: usize) {
    unsafe {
        libc::kill(child.id() as libc::pid_t, libc::SIGTERM);
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        match child.try_wait() {
            Ok(Some(_)) => return,
            Ok(None) => {}
            Err(_) => return,
        }
        if std::time::Instant::now() >= deadline {
            crate::log_warn!("sampler child {worker_id} ignored SIGTERM; killing");
            let _ = child.kill();
            let _ = child.wait();
            return;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

// ------------------------------------------------------- walle serve

/// What a standalone `walle serve` run saw, for the exit report.
pub struct ServeSummary {
    /// Chunks received from remote samplers and drained (a standalone
    /// daemon has no learner to consume them).
    pub chunks_drained: u64,
    /// Pool dispatch stats + wire counters.
    pub report: InferenceReport,
}

/// The `walle serve` body: a standalone policy daemon. Publishes the
/// algorithm's initial policy, serves any number of `walle sample
/// --connect` processes, and — with `watch_dir` — hot-swaps to every
/// newer checkpoint that lands there (a colocated learner's
/// `--checkpoint-every` output) through the normal publish/epoch
/// machinery. Runs until `shutdown` flips (SIGINT/SIGTERM in main.rs).
pub fn serve_forever(
    algo: &dyn Algorithm,
    cfg: &TrainConfig,
    factory: &dyn BackendFactory,
    sock: &Path,
    watch_dir: Option<&Path>,
    shutdown: &AtomicBool,
) -> Result<ServeSummary> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    crate::nn::kernels::set_mode(cfg.kernels.mode());
    crate::env::batch::set_engine(cfg.env_engine.engine());
    let listener = bind_socket(sock)?;
    let queue: Channel<ExperienceChunk> = Channel::new(cfg.queue_capacity);
    let store = PolicyStore::new();
    if cfg.infer_precision == crate::config::InferPrecision::Int8 {
        let q = algo.quantizer(factory, cfg).ok_or_else(|| {
            anyhow::anyhow!(
                "--infer-precision int8 is not supported by algorithm {:?}",
                cfg.algo
            )
        })?;
        store.set_quantizer(q);
    }
    let stop = AtomicBool::new(false);
    // the daemon keeps its learner alive for the whole run: checkpoint
    // learner blobs are opaque, so adopting one means load_state +
    // re-publishing final_params/final_norm through THIS instance
    let mut learner = algo.make_learner(factory, cfg)?;
    learner.publish_initial(&store);
    let mut last_ck_version = store.version();
    let chunks = AtomicU64::new(0);
    let fingerprint = run_fingerprint(cfg);

    let pool = build_pool(cfg, factory);
    // the ctx is MOVED into the accept loop below and fully dropped by
    // the time the scope joins — the stash it carries is what keeps the
    // pre-registered clients (and thus the shard serve loops) alive, so
    // no clone may survive the scope; only the metrics Arc does
    let ctx = DaemonCtx::new(cfg, pool.clone(), &store, &queue, &stop);
    let metrics = ctx.metrics.clone();
    std::thread::scope(|scope| {
        for (idx, shard) in pool.shards().iter().enumerate() {
            let shard = shard.clone();
            let store = &store;
            scope.spawn(move || {
                if let Err(e) = shard.serve_algo(algo, factory, store) {
                    crate::log_error!("inference shard {idx} failed: {e:#}");
                }
            });
        }
        scope.spawn(move || accept_loop(scope, listener, ctx));
        // drain remote chunks: a standalone daemon has no learner loop
        // consuming the queue, and letting it fill would stall every
        // connected sampler at the backpressure point
        let chunks = &chunks;
        let queue_ref = &queue;
        scope.spawn(move || {
            while queue_ref.pop().is_ok() {
                chunks.fetch_add(1, Ordering::Relaxed);
            }
        });

        crate::log_info!(
            "serving {} ({}) on {} — {} sampler slot(s), {} shard(s){}",
            cfg.env,
            cfg.algo.name(),
            sock.display(),
            cfg.samplers,
            pool.shard_count(),
            match watch_dir {
                Some(d) => format!(", watching {} for checkpoints", d.display()),
                None => String::new(),
            }
        );
        while !shutdown.load(Ordering::Relaxed) {
            if let Some(dir) = watch_dir {
                adopt_checkpoint(dir, &mut learner, &store, &fingerprint, &mut last_ck_version);
            }
            std::thread::sleep(Duration::from_millis(200));
        }
        crate::log_info!("shutdown signal received; closing the daemon");
        stop.store(true, Ordering::Relaxed);
        queue.close();
        // scope join: accept/connection threads exit on `stop` within a
        // read probe; dropping the last stash clone releases the
        // pre-registered clients, which lets every shard's serve loop
        // exit
    });
    let _ = std::fs::remove_file(sock);
    let mut rep = pool.report();
    metrics.merge_into(&mut rep);
    Ok(ServeSummary {
        chunks_drained: chunks.load(Ordering::Relaxed),
        report: rep,
    })
}

/// Adopt the newest checkpoint in `dir` if it is newer than the last
/// version this daemon published from the watch path. Non-fatal on any
/// error (the directory may simply be empty so far).
fn adopt_checkpoint(
    dir: &Path,
    learner: &mut Box<dyn LearnerDriver>,
    store: &PolicyStore,
    fingerprint: &RunFingerprint,
    last: &mut u64,
) {
    let ck = match checkpoint::load_latest(dir) {
        Ok(c) => c,
        Err(_) => return, // nothing (valid) there yet
    };
    if ck.version <= *last {
        return;
    }
    if ck.fingerprint != *fingerprint {
        crate::log_warn!(
            "ignoring checkpoint in {}: {}",
            dir.display(),
            wire::fingerprint_mismatch(fingerprint, &ck.fingerprint)
        );
        *last = ck.version; // warn once per version, not every 200ms
        return;
    }
    if let Err(e) = learner.load_state(&ck.learner) {
        crate::log_warn!("checkpoint in {} failed to load: {e:#}", dir.display());
        *last = ck.version;
        return;
    }
    store.resume_at(ck.version.saturating_sub(1));
    let v = store.publish(learner.final_params(), learner.final_norm());
    *last = v;
    crate::log_info!(
        "adopted checkpoint (iteration {}) as policy version {v}",
        ck.iteration
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_sidecar_appends_suffix() {
        let p = config_sidecar(Path::new("/tmp/walle-x.sock"));
        assert_eq!(p, Path::new("/tmp/walle-x.sock.config.json"));
    }

    #[test]
    fn default_socket_paths_are_unique() {
        let a = default_socket_path();
        let b = default_socket_path();
        assert_ne!(a, b);
        assert!(a.to_string_lossy().ends_with(".sock"));
    }

    #[test]
    fn fingerprint_mirrors_config_fields() {
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.samplers = 3;
        cfg.envs_per_sampler = 2;
        cfg.seed = 77;
        let fp = run_fingerprint(&cfg);
        assert_eq!(fp.env, "pendulum");
        assert_eq!(fp.samplers, 3);
        assert_eq!(fp.envs_per_sampler, 2);
        assert_eq!(fp.seed, 77);
        assert_eq!(fp.algo, cfg.algo.name());
    }

    #[test]
    fn wire_metrics_merge_into_report() {
        let m = WireMetrics::new();
        m.count_in(100);
        m.count_out(5000);
        m.count_handshake();
        m.count_disconnect();
        let mut rep = InferenceReport::new(4);
        m.merge_into(&mut rep);
        assert_eq!(rep.wire_frames_in, 1);
        assert_eq!(rep.wire_frames_out, 1);
        assert_eq!(rep.wire_bytes_in, 100);
        assert_eq!(rep.wire_bytes_out, 5000);
        assert_eq!(rep.wire_handshakes, 1);
        assert_eq!(rep.wire_disconnects, 1);
        assert_eq!(rep.wire_frame_bytes.count(), 2);
        assert!(rep.has_wire_traffic());
    }

    #[test]
    fn bind_socket_unlinks_stale_and_rejects_live() {
        let sock = default_socket_path();
        // stale file nobody answers on
        std::fs::write(&sock, b"").unwrap();
        let listener = bind_socket(&sock).expect("stale socket must be reclaimed");
        // a second daemon must refuse the live socket
        let err = bind_socket(&sock).unwrap_err();
        assert!(err.to_string().contains("already served"), "{err:#}");
        drop(listener);
        let _ = std::fs::remove_file(&sock);
    }
}
