//! Wire protocol of the policy daemon: length-prefixed binary frames
//! over Unix-domain sockets.
//!
//! Every frame is `[u32 payload length][u8 frame type][type-specific
//! payload]`, all little-endian through [`crate::util::bytes`] — the
//! same codec checkpoints use, so every lane that must survive the hop
//! bitwise (obs/act/logp/value slabs, normalizer snapshots, Welford
//! stats) round-trips through `f32::to_le_bytes` exactly.
//!
//! The conversation is strictly client-initiated:
//!
//! * **Handshake** — the client opens with [`Frame::Hello`] carrying the
//!   protocol version, its [`RunFingerprint`] (env / algo / fleet shape /
//!   seed), its worker id and rows-per-request M. The daemon answers
//!   [`Frame::HelloOk`] (current policy version + normalizer snapshot)
//!   or [`Frame::HelloErr`] with an actionable message and closes. A
//!   fingerprint mismatch is rejected here, before any slab crosses the
//!   socket — garbage rows under a different seed or env would corrupt
//!   every downstream stream silently.
//! * **Actor connections** (`PeerKind::Actor`) then alternate
//!   [`Frame::ActReq`] → [`Frame::ActResp`] for the sampler hot loop and
//!   push [`Frame::Chunk`] frames (fire-and-forget) for finished
//!   experience chunks. Every act response carries the serving snapshot's
//!   version + epoch so the client-side hot loop can run the SAME
//!   version-cut logic it runs in-process.
//! * **Subscriber connections** (`PeerKind::Subscriber`) alternate
//!   [`Frame::WaitNewer`] → [`Frame::Version`]: a long-poll that mirrors
//!   the daemon's `PolicyStore` publishes into the client process so the
//!   unmodified sampler sync-stall (`refresh_policy`) unblocks exactly
//!   when the daemon's learner publishes.

use crate::algo::normalizer::{NormSnapshot, RunningNorm};
use crate::algo::rollout::{ChunkEnd, ExperienceChunk};
use crate::runtime::checkpoint::RunFingerprint;
use crate::util::bytes::{ByteReader, ByteWriter};
use anyhow::{bail, Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};

/// Bumped on any incompatible frame-layout change; the handshake rejects
/// mismatches on both ends.
pub const PROTO_VERSION: u32 = 1;

/// Upper bound on a single frame's payload — a length prefix beyond this
/// is treated as stream corruption, not an allocation request.
pub const MAX_FRAME_LEN: u32 = 64 * 1024 * 1024;

const T_HELLO: u8 = 1;
const T_HELLO_OK: u8 = 2;
const T_HELLO_ERR: u8 = 3;
const T_ACT_REQ: u8 = 4;
const T_ACT_RESP: u8 = 5;
const T_ACT_ERR: u8 = 6;
const T_CHUNK: u8 = 7;
const T_WAIT_NEWER: u8 = 8;
const T_VERSION: u8 = 9;

/// What a connection is for, declared in the handshake.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerKind {
    /// Sampler hot loop: act requests + chunk pushes.
    Actor,
    /// Version long-poll: mirrors daemon publishes into the client.
    Subscriber,
}

/// One act response as it crosses the wire: the daemon-side
/// `ActResponse` lanes plus version/epoch metadata, and — only on the
/// first response after a version change — the new normalizer snapshot,
/// so the client can rebuild its policy snapshot without a round trip.
#[derive(Debug, Clone)]
pub struct ActRespWire {
    pub version: u64,
    pub epoch: u64,
    pub server_busy_secs: f64,
    pub rows: usize,
    pub action: Vec<f32>,
    pub logp: Vec<f32>,
    pub value: Vec<f32>,
    pub mean: Vec<f32>,
    /// Server-side normalized observation rows (`[rows * obs_dim]`) —
    /// the hot loop records these, so normalization happens exactly once
    /// and exactly where it does in-process.
    pub norm_obs: Vec<f32>,
    /// Present iff `version` differs from the previous response on this
    /// connection (and on the first response).
    pub norm: Option<NormSnapshot>,
}

/// Every message the daemon protocol speaks. See the module docs for the
/// conversation structure.
#[derive(Debug, Clone)]
pub enum Frame {
    Hello {
        kind: PeerKind,
        fingerprint: RunFingerprint,
        worker_id: usize,
        m: usize,
    },
    HelloOk {
        version: u64,
        norm: NormSnapshot,
    },
    HelloErr {
        message: String,
    },
    ActReq {
        rows: usize,
        obs: Vec<f32>,
        noise: Vec<f32>,
    },
    ActResp(ActRespWire),
    ActErr {
        message: String,
    },
    Chunk(Box<ExperienceChunk>),
    WaitNewer {
        seen: u64,
    },
    Version {
        version: u64,
        norm: NormSnapshot,
    },
}

impl Frame {
    /// Short type name for diagnostics ("expected ActResp, got {}").
    pub fn kind_name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "Hello",
            Frame::HelloOk { .. } => "HelloOk",
            Frame::HelloErr { .. } => "HelloErr",
            Frame::ActReq { .. } => "ActReq",
            Frame::ActResp(_) => "ActResp",
            Frame::ActErr { .. } => "ActErr",
            Frame::Chunk(_) => "Chunk",
            Frame::WaitNewer { .. } => "WaitNewer",
            Frame::Version { .. } => "Version",
        }
    }

    /// Serialize to a frame payload (no length prefix; see
    /// [`write_frame`]).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match self {
            Frame::Hello {
                kind,
                fingerprint,
                worker_id,
                m,
            } => {
                w.put_u32(T_HELLO as u32);
                w.put_u32(PROTO_VERSION);
                w.put_u32(match kind {
                    PeerKind::Actor => 0,
                    PeerKind::Subscriber => 1,
                });
                fingerprint.write(&mut w);
                w.put_usize(*worker_id);
                w.put_usize(*m);
            }
            Frame::HelloOk { version, norm } => {
                w.put_u32(T_HELLO_OK as u32);
                w.put_u64(*version);
                put_norm_snapshot(&mut w, norm);
            }
            Frame::HelloErr { message } => {
                w.put_u32(T_HELLO_ERR as u32);
                w.put_str(message);
            }
            Frame::ActReq { rows, obs, noise } => {
                w.put_u32(T_ACT_REQ as u32);
                w.put_usize(*rows);
                w.put_f32s(obs);
                w.put_f32s(noise);
            }
            Frame::ActResp(r) => {
                w.put_u32(T_ACT_RESP as u32);
                w.put_u64(r.version);
                w.put_u64(r.epoch);
                w.put_f64(r.server_busy_secs);
                w.put_usize(r.rows);
                w.put_f32s(&r.action);
                w.put_f32s(&r.logp);
                w.put_f32s(&r.value);
                w.put_f32s(&r.mean);
                w.put_f32s(&r.norm_obs);
                match &r.norm {
                    Some(n) => {
                        w.put_u32(1);
                        put_norm_snapshot(&mut w, n);
                    }
                    None => w.put_u32(0),
                }
            }
            Frame::ActErr { message } => {
                w.put_u32(T_ACT_ERR as u32);
                w.put_str(message);
            }
            Frame::Chunk(c) => {
                w.put_u32(T_CHUNK as u32);
                w.put_usize(c.sampler_id);
                w.put_usize(c.env_slot);
                w.put_u64(c.policy_version);
                w.put_f32s(&c.obs);
                w.put_f32s(&c.act);
                w.put_f32s(&c.rew);
                w.put_f32s(&c.logp);
                w.put_f32s(&c.value);
                w.put_u32(match c.end {
                    ChunkEnd::Terminal => 0,
                    ChunkEnd::Truncated => 1,
                    ChunkEnd::Continuation => 2,
                });
                w.put_f32(c.bootstrap_value);
                w.put_f32s(&c.episode_returns);
                w.put_usize(c.episode_lengths.len());
                for &l in &c.episode_lengths {
                    w.put_usize(l);
                }
                match &c.obs_stats {
                    Some(stats) => {
                        w.put_u32(1);
                        stats.save_state(&mut w);
                    }
                    None => w.put_u32(0),
                }
                w.put_f64(c.busy_secs);
            }
            Frame::WaitNewer { seen } => {
                w.put_u32(T_WAIT_NEWER as u32);
                w.put_u64(*seen);
            }
            Frame::Version { version, norm } => {
                w.put_u32(T_VERSION as u32);
                w.put_u64(*version);
                put_norm_snapshot(&mut w, norm);
            }
        }
        w.into_vec()
    }

    /// Parse a frame payload produced by [`Frame::encode`].
    pub fn decode(payload: &[u8]) -> Result<Frame> {
        let mut r = ByteReader::new(payload);
        let tag = r.read_u32()? as u8;
        let frame = match tag {
            T_HELLO => {
                let proto = r.read_u32()?;
                if proto != PROTO_VERSION {
                    bail!(
                        "peer speaks wire protocol v{proto}, this build speaks \
                         v{PROTO_VERSION} — rebuild both ends from the same source"
                    );
                }
                let kind = match r.read_u32()? {
                    0 => PeerKind::Actor,
                    1 => PeerKind::Subscriber,
                    k => bail!("unknown peer kind {k} in Hello"),
                };
                Frame::Hello {
                    kind,
                    fingerprint: RunFingerprint::read(&mut r)?,
                    worker_id: r.read_usize()?,
                    m: r.read_usize()?,
                }
            }
            T_HELLO_OK => Frame::HelloOk {
                version: r.read_u64()?,
                norm: read_norm_snapshot(&mut r)?,
            },
            T_HELLO_ERR => Frame::HelloErr {
                message: r.read_str()?,
            },
            T_ACT_REQ => Frame::ActReq {
                rows: r.read_usize()?,
                obs: r.read_f32s()?,
                noise: r.read_f32s()?,
            },
            T_ACT_RESP => {
                let version = r.read_u64()?;
                let epoch = r.read_u64()?;
                let server_busy_secs = r.read_f64()?;
                let rows = r.read_usize()?;
                let action = r.read_f32s()?;
                let logp = r.read_f32s()?;
                let value = r.read_f32s()?;
                let mean = r.read_f32s()?;
                let norm_obs = r.read_f32s()?;
                let norm = match r.read_u32()? {
                    0 => None,
                    _ => Some(read_norm_snapshot(&mut r)?),
                };
                Frame::ActResp(ActRespWire {
                    version,
                    epoch,
                    server_busy_secs,
                    rows,
                    action,
                    logp,
                    value,
                    mean,
                    norm_obs,
                    norm,
                })
            }
            T_ACT_ERR => Frame::ActErr {
                message: r.read_str()?,
            },
            T_CHUNK => {
                let sampler_id = r.read_usize()?;
                let env_slot = r.read_usize()?;
                let policy_version = r.read_u64()?;
                let obs = r.read_f32s()?;
                let act = r.read_f32s()?;
                let rew = r.read_f32s()?;
                let logp = r.read_f32s()?;
                let value = r.read_f32s()?;
                let end = match r.read_u32()? {
                    0 => ChunkEnd::Terminal,
                    1 => ChunkEnd::Truncated,
                    2 => ChunkEnd::Continuation,
                    e => bail!("unknown chunk end tag {e}"),
                };
                let bootstrap_value = r.read_f32()?;
                let episode_returns = r.read_f32s()?;
                let n = r.read_usize()?;
                if n > r.remaining() / 8 {
                    bail!("corrupt episode-length count {n}");
                }
                let mut episode_lengths = Vec::with_capacity(n);
                for _ in 0..n {
                    episode_lengths.push(r.read_usize()?);
                }
                let obs_stats = match r.read_u32()? {
                    0 => None,
                    _ => Some(RunningNorm::load_state(&mut r)?),
                };
                let busy_secs = r.read_f64()?;
                Frame::Chunk(Box::new(ExperienceChunk {
                    sampler_id,
                    env_slot,
                    policy_version,
                    obs,
                    act,
                    rew,
                    logp,
                    value,
                    end,
                    bootstrap_value,
                    episode_returns,
                    episode_lengths,
                    obs_stats,
                    busy_secs,
                }))
            }
            T_WAIT_NEWER => Frame::WaitNewer {
                seen: r.read_u64()?,
            },
            T_VERSION => Frame::Version {
                version: r.read_u64()?,
                norm: read_norm_snapshot(&mut r)?,
            },
            t => bail!("unknown frame type {t}"),
        };
        Ok(frame)
    }
}

fn put_norm_snapshot(w: &mut ByteWriter, n: &NormSnapshot) {
    w.put_f32s(&n.mean);
    w.put_f32s(&n.inv_std);
    w.put_f32(n.clip);
    w.put_u64(n.count);
}

fn read_norm_snapshot(r: &mut ByteReader<'_>) -> Result<NormSnapshot> {
    Ok(NormSnapshot {
        mean: r.read_f32s()?,
        inv_std: r.read_f32s()?,
        clip: r.read_f32()?,
        count: r.read_u64()?,
    })
}

/// Write one frame (length prefix + payload) and flush. The whole frame
/// goes out through a single `write_all` per part; callers that share a
/// stream between threads must serialize whole-frame writes externally.
pub fn write_frame(stream: &mut impl Write, frame: &Frame) -> std::io::Result<usize> {
    let payload = frame.encode();
    let len = payload.len() as u32;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(&payload)?;
    stream.flush()?;
    Ok(payload.len() + 4)
}

/// Outcome of [`read_frame`].
pub enum ReadOutcome {
    /// A full frame, plus the bytes it occupied on the wire.
    Frame(Frame, usize),
    /// Clean EOF at a frame boundary: the peer hung up.
    Eof,
}

/// Read one frame. Timeout errors on the stream (the caller is expected
/// to have set a read timeout) are retried until `stop` flips, so a
/// blocked reader observes shutdown within one timeout interval instead
/// of hanging forever. EOF mid-frame is an error; EOF at a frame
/// boundary returns [`ReadOutcome::Eof`].
pub fn read_frame(stream: &mut impl Read, stop: &AtomicBool) -> Result<ReadOutcome> {
    let mut len_buf = [0u8; 4];
    if !read_full(stream, &mut len_buf, stop, true)? {
        return Ok(ReadOutcome::Eof);
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME_LEN {
        bail!("frame length {len} exceeds the {MAX_FRAME_LEN}-byte cap (corrupt stream?)");
    }
    let mut payload = vec![0u8; len as usize];
    if !read_full(stream, &mut payload, stop, false)? {
        bail!("peer closed the socket mid-frame ({len}-byte payload truncated)");
    }
    let frame = Frame::decode(&payload).context("decoding wire frame")?;
    Ok(ReadOutcome::Frame(frame, payload.len() + 4))
}

/// Fill `buf` completely. Returns Ok(false) on EOF before the first byte
/// when `eof_ok`; errors on EOF mid-buffer. Timeouts re-check `stop`.
fn read_full(
    stream: &mut impl Read,
    buf: &mut [u8],
    stop: &AtomicBool,
    eof_ok: bool,
) -> Result<bool> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 && eof_ok {
                    return Ok(false);
                }
                bail!("peer closed the socket mid-frame");
            }
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted =>
            {
                if stop.load(Ordering::Relaxed) {
                    bail!("shutting down while waiting for a frame");
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

/// Render a fingerprint mismatch as the actionable, both-ends error the
/// handshake contract requires: every differing field is named with the
/// daemon's value and the client's value side by side.
pub fn fingerprint_mismatch(ours: &RunFingerprint, theirs: &RunFingerprint) -> String {
    let mut diffs = Vec::new();
    if ours.env != theirs.env {
        diffs.push(format!("env {:?} vs client {:?}", ours.env, theirs.env));
    }
    if ours.algo != theirs.algo {
        diffs.push(format!("algo {:?} vs client {:?}", ours.algo, theirs.algo));
    }
    if ours.samplers != theirs.samplers {
        diffs.push(format!(
            "samplers {} vs client {}",
            ours.samplers, theirs.samplers
        ));
    }
    if ours.envs_per_sampler != theirs.envs_per_sampler {
        diffs.push(format!(
            "envs_per_sampler {} vs client {}",
            ours.envs_per_sampler, theirs.envs_per_sampler
        ));
    }
    if ours.seed != theirs.seed {
        diffs.push(format!("seed {} vs client {}", ours.seed, theirs.seed));
    }
    format!(
        "run fingerprint mismatch ({}) — a daemon only serves clients from the \
         SAME run identity; point --connect at the daemon for this config, or \
         restart the daemon with the client's env/algo/fleet-shape/seed",
        diffs.join(", ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn norm() -> NormSnapshot {
        NormSnapshot {
            mean: vec![0.5, -1.25, 3.0],
            inv_std: vec![1.0, 0.125, 2.5],
            clip: 10.0,
            count: 4096,
        }
    }

    fn fp() -> RunFingerprint {
        RunFingerprint {
            env: "pendulum".into(),
            algo: "ppo".into(),
            samplers: 2,
            envs_per_sampler: 2,
            seed: 29,
        }
    }

    fn round_trip(f: &Frame) -> Frame {
        Frame::decode(&f.encode()).unwrap()
    }

    #[test]
    fn hello_round_trips() {
        let f = Frame::Hello {
            kind: PeerKind::Actor,
            fingerprint: fp(),
            worker_id: 1,
            m: 2,
        };
        match round_trip(&f) {
            Frame::Hello {
                kind,
                fingerprint,
                worker_id,
                m,
            } => {
                assert_eq!(kind, PeerKind::Actor);
                assert_eq!(fingerprint, fp());
                assert_eq!((worker_id, m), (1, 2));
            }
            other => panic!("wrong frame {}", other.kind_name()),
        }
    }

    #[test]
    fn act_resp_round_trips_bitwise() {
        let f = Frame::ActResp(ActRespWire {
            version: 7,
            epoch: 3,
            server_busy_secs: 0.125,
            rows: 2,
            action: vec![0.1, -0.0],
            logp: vec![f32::MIN_POSITIVE, -2.5],
            value: vec![1.0e-8, 9.75],
            mean: vec![0.25, 0.5],
            norm_obs: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            norm: Some(norm()),
        });
        match round_trip(&f) {
            Frame::ActResp(r) => {
                assert_eq!(r.version, 7);
                assert_eq!(r.epoch, 3);
                assert_eq!(r.rows, 2);
                assert_eq!(r.action[1].to_bits(), (-0.0f32).to_bits());
                assert_eq!(r.logp, vec![f32::MIN_POSITIVE, -2.5]);
                assert_eq!(r.norm_obs.len(), 6);
                let n = r.norm.unwrap();
                assert_eq!(n.mean, norm().mean);
                assert_eq!(n.count, 4096);
            }
            other => panic!("wrong frame {}", other.kind_name()),
        }
    }

    #[test]
    fn chunk_round_trips_with_welford_stats() {
        let mut stats = RunningNorm::new(3, 10.0);
        for row in [[0.1f32, 0.2, 0.3], [0.4, 0.5, 0.6]] {
            stats.update(&row);
        }
        let c = ExperienceChunk {
            sampler_id: 1,
            env_slot: 0,
            policy_version: 5,
            obs: vec![1.0; 6],
            act: vec![0.5, -0.5],
            rew: vec![-1.0, -0.5],
            logp: vec![0.0, 0.1],
            value: vec![2.0, 2.5],
            end: ChunkEnd::Truncated,
            bootstrap_value: 1.5,
            episode_returns: vec![-42.0],
            episode_lengths: vec![200],
            obs_stats: Some(stats.clone()),
            busy_secs: 0.25,
        };
        match round_trip(&Frame::Chunk(Box::new(c))) {
            Frame::Chunk(back) => {
                assert_eq!(back.sampler_id, 1);
                assert_eq!(back.policy_version, 5);
                assert_eq!(back.end, ChunkEnd::Truncated);
                assert_eq!(back.bootstrap_value, 1.5);
                assert_eq!(back.episode_lengths, vec![200]);
                // Welford stats survive bitwise: re-serializing the
                // restored stats reproduces the original byte stream
                let mut a = ByteWriter::new();
                stats.save_state(&mut a);
                let mut b = ByteWriter::new();
                back.obs_stats.unwrap().save_state(&mut b);
                assert_eq!(a.into_vec(), b.into_vec());
            }
            other => panic!("wrong frame {}", other.kind_name()),
        }
    }

    #[test]
    fn hello_rejects_other_proto_versions() {
        let mut payload = Frame::Hello {
            kind: PeerKind::Subscriber,
            fingerprint: fp(),
            worker_id: 0,
            m: 1,
        }
        .encode();
        payload[4] ^= 0xFF; // the proto-version field follows the tag
        let err = Frame::decode(&payload).unwrap_err().to_string();
        assert!(err.contains("wire protocol"), "unhelpful error: {err}");
    }

    #[test]
    fn corrupt_and_unknown_frames_error_cleanly() {
        assert!(Frame::decode(&[]).is_err());
        let mut w = ByteWriter::new();
        w.put_u32(200); // unknown tag
        assert!(Frame::decode(&w.into_vec()).is_err());
        // truncated ActResp
        let f = Frame::ActResp(ActRespWire {
            version: 1,
            epoch: 0,
            server_busy_secs: 0.0,
            rows: 1,
            action: vec![1.0],
            logp: vec![],
            value: vec![],
            mean: vec![],
            norm_obs: vec![1.0, 2.0, 3.0],
            norm: None,
        });
        let payload = f.encode();
        assert!(Frame::decode(&payload[..payload.len() - 3]).is_err());
    }

    #[test]
    fn stream_round_trip_over_a_socket_pair() {
        let (mut a, mut b) = std::os::unix::net::UnixStream::pair().unwrap();
        let stop = AtomicBool::new(false);
        let f = Frame::WaitNewer { seen: 9 };
        let wrote = write_frame(&mut a, &f).unwrap();
        match read_frame(&mut b, &stop).unwrap() {
            ReadOutcome::Frame(Frame::WaitNewer { seen }, n) => {
                assert_eq!(seen, 9);
                assert_eq!(n, wrote);
            }
            _ => panic!("expected WaitNewer"),
        }
        drop(a);
        assert!(matches!(
            read_frame(&mut b, &stop).unwrap(),
            ReadOutcome::Eof
        ));
    }

    #[test]
    fn mismatch_message_names_every_differing_field() {
        let mut theirs = fp();
        theirs.seed = 30;
        theirs.env = "halfcheetah".into();
        let msg = fingerprint_mismatch(&fp(), &theirs);
        assert!(msg.contains("seed 29 vs client 30"), "{msg}");
        assert!(msg.contains("env"), "{msg}");
        assert!(!msg.contains("algo \""), "algo matches, must not be listed: {msg}");
    }
}
