//! Pool-wide policy epochs: the gate that makes `--infer-shards` a pure
//! performance knob even while the learner publishes mid-run.
//!
//! PR 3's sharded [`InferencePool`](crate::runtime::inference_server::InferencePool)
//! let every shard observe the [`PolicyStore`] independently, once per
//! dispatch. Under a frozen policy that is invisible, but the moment the
//! learner publishes, two shards could run the *same sim tick* under
//! *different* parameter versions — per-worker chunk streams stayed
//! single-version, yet the fleet-wide experience distribution depended on
//! S (the exact divergence flagged in ROADMAP's Open items).
//!
//! The [`EpochGate`] closes that seam. One gate is shared by all S shards
//! of a pool:
//!
//! 1. A learner publish does not reach shards directly — the first shard
//!    to notice it lands it as a **proposed** epoch.
//! 2. Each shard **acknowledges** the proposal at its next dispatch
//!    boundary, a point where its previous window is fully drained (the
//!    serve loop is synchronous: gather → forward → scatter). Idle shards
//!    ack from their wait loop ([`EpochGate::poll`]); exiting shards
//!    deregister ([`EpochGate::leave`]) so a dead peer can never wedge
//!    the barrier.
//! 3. Only when **every live shard** has acked does the gate **flip**:
//!    the proposed snapshot becomes current, the pool epoch increments,
//!    and all parked shards resume. Until then, acked shards block
//!    ([`EpochGate::acquire`]) — the dispatch barrier that guarantees no
//!    forward anywhere in the pool runs under the new version while
//!    another shard still serves the old one.
//!
//! Every [`ActResponse`](crate::runtime::inference_server::ActResponse)
//! carries the `(epoch, version)` pair of its dispatch, so sampler
//! workers cut chunks on epoch movement instead of polling the store.
//! The time a shard spends parked at the barrier is surfaced as the
//! `flip_stall_us` histogram, and the staleness of the served snapshot
//! against the newest publish as `epoch_lag` (both in
//! [`InferenceReport`](crate::coordinator::metrics::InferenceReport)).
//!
//! The worst-case stall per flip is one straggler-cut window (a shard
//! that is mid-gather finishes its window no later than the cut fires,
//! then acks) or the serve loop's ~5ms idle poll for a shard with no
//! pending requests, whichever applies. `--infer-epoch shard` bypasses
//! the gate entirely and restores the PR 3 per-shard observation (an
//! escape hatch; per-chunk single-version semantics hold either way).

use crate::coordinator::policy_store::{PolicySnapshot, PolicyStore};
use crate::util::{cv_wait, plock};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How a shared-inference pool observes the [`PolicyStore`]
/// (`--infer-epoch`, resolved from `config::InferEpoch`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EpochMode {
    /// Pool-wide epochs (default): a publish becomes a proposed epoch and
    /// ALL shards flip to it on the same dispatch boundary.
    Pool,
    /// Each shard observes the store independently (the pre-epoch
    /// behavior): shards may adopt a publish a dispatch apart.
    Shard,
}

/// What [`EpochGate::acquire`] hands a shard for one dispatch.
pub struct EpochLease {
    /// Snapshot every row of the dispatch is evaluated under.
    pub snapshot: Arc<PolicySnapshot>,
    /// Pool epoch of the dispatch (1-based; bumps exactly once per
    /// adopted publish, in lockstep across all shards).
    pub epoch: u64,
    /// Microseconds this shard spent parked at the flip barrier, when it
    /// had to wait for peers on this acquire (None = no stall).
    pub flip_stall_us: Option<f64>,
}

struct GateState {
    /// Ack period in dispatches (`--flip-schedule`; 0 = ack at every
    /// dispatch boundary). With a period P, a shard acknowledges a
    /// proposed epoch only when its dispatch count is a multiple of P, so
    /// flips land on a deterministic dispatch schedule instead of
    /// wherever the publish happened to race the serve loops.
    schedule: u64,
    /// Per-shard dispatch counter (lifetime; survives shard respawns so
    /// the schedule stays monotonic across a revival).
    dispatches: Vec<u64>,
    /// Current pool epoch (0 until the first snapshot lands).
    epoch: u64,
    /// Snapshot every shard serves under the current epoch.
    cur: Option<Arc<PolicySnapshot>>,
    /// Snapshot parked behind the barrier (None = no flip in progress).
    proposed: Option<Arc<PolicySnapshot>>,
    /// Per-shard: reached a dispatch boundary since `proposed` landed.
    acked: Vec<bool>,
    /// Per-shard: still serving. A shard leaves on ANY exit path —
    /// clean shutdown, backend error, or panic — so the barrier only
    /// ever waits on shards that can still make progress.
    live: Vec<bool>,
    /// Completed flips (diagnostics and tests).
    flips: u64,
}

impl GateState {
    fn all_live_acked(&self) -> bool {
        self.live.iter().zip(&self.acked).all(|(&l, &a)| !l || a)
    }

    /// Promote the proposed snapshot: current moves, epoch bumps, acks
    /// reset for the next proposal cycle.
    fn flip(&mut self) {
        if let Some(next) = self.proposed.take() {
            self.cur = Some(next);
            self.epoch += 1;
            self.flips += 1;
            for a in self.acked.iter_mut() {
                *a = false;
            }
        }
    }

    /// Adopt the very first snapshot barrier-free (there is no older
    /// version anyone could be serving), or land a newer publish as the
    /// proposal. Returns true while a proposal is pending. Intermediate
    /// versions are superseded: the proposal is whatever the store holds
    /// when it lands, and anything newer waits for the next cycle.
    ///
    /// The proposal decision is made on the SNAPSHOT's own version from a
    /// single `latest()` read — never on `PolicyStore::version()`, which
    /// is bumped before the slot is written and could otherwise race a
    /// mid-publish learner into proposing the old snapshot (a spurious
    /// epoch flip with an unchanged version). The atomic counter is used
    /// only as a cheap pre-filter to skip the slot lock on the hot path.
    fn observe(&mut self, store: &PolicyStore) -> bool {
        match &self.cur {
            None => {
                if let Some(s) = store.latest() {
                    self.cur = Some(s);
                    self.epoch = 1;
                }
                false
            }
            Some(cur) => {
                if self.proposed.is_none() && store.version() > cur.version {
                    self.proposed = store.latest().filter(|s| s.version > cur.version);
                }
                self.proposed.is_some()
            }
        }
    }
}

/// The pool-wide epoch barrier shared by all S shards (see the module
/// docs for the protocol).
pub struct EpochGate {
    state: Mutex<GateState>,
    changed: Condvar,
}

impl EpochGate {
    pub fn new(shards: usize) -> EpochGate {
        EpochGate::with_schedule(shards, 0)
    }

    /// A gate whose shards acknowledge proposals only every `schedule`
    /// dispatches (0 = every dispatch boundary; see `--flip-schedule`).
    pub fn with_schedule(shards: usize, schedule: u64) -> EpochGate {
        EpochGate {
            state: Mutex::new(GateState {
                schedule,
                dispatches: vec![0; shards],
                epoch: 0,
                cur: None,
                proposed: None,
                acked: vec![false; shards],
                live: vec![true; shards],
                flips: 0,
            }),
            changed: Condvar::new(),
        }
    }

    /// Called by shard `shard` at a dispatch boundary (its previous
    /// window fully drained): returns the snapshot + epoch for the next
    /// dispatch. When a publish is parked behind the barrier this acks
    /// the boundary and BLOCKS until every live shard has acked — no
    /// shard dispatches under the new version while another still serves
    /// the old one. Also blocks before the first publish (the pool has
    /// nothing to serve yet).
    ///
    /// With `--flip-schedule P`, a shard off its period keeps dispatching
    /// under the current epoch while a proposal is parked — it only acks
    /// (and blocks) when its dispatch count reaches a multiple of P.
    pub fn acquire(&self, shard: usize, store: &PolicyStore) -> EpochLease {
        let mut g = plock(&self.state);
        g.dispatches[shard] += 1;
        let at_boundary = g.schedule == 0 || g.dispatches[shard] % g.schedule == 0;
        let mut stalled: Option<Instant> = None;
        loop {
            let pending = g.observe(store);
            if g.cur.is_some() {
                if !pending || (!at_boundary && !g.acked[shard]) {
                    return EpochLease {
                        snapshot: g.cur.clone().expect("checked above"),
                        epoch: g.epoch,
                        flip_stall_us: stalled.map(|t0| t0.elapsed().as_secs_f64() * 1e6),
                    };
                }
                g.acked[shard] = true;
                if g.all_live_acked() {
                    g.flip();
                    self.changed.notify_all();
                    continue; // next pass returns the flipped snapshot
                }
                stalled.get_or_insert_with(Instant::now);
            }
            // park: waiting for the first publish or for peers to ack.
            // The timeout is a safety valve (leave()/poll() notify on
            // every state change), so a missed wakeup degrades to a
            // bounded delay, never a hang.
            g = cv_wait(&self.changed, g, Duration::from_millis(10));
        }
    }

    /// Non-blocking participation for an idle shard (empty request
    /// queue): lands proposals, acks its — trivially drained — boundary,
    /// and completes the flip when it is the last acker. Called from the
    /// serve loop's idle wait so a shard with parked workers (sync-mode
    /// barrier, drained fleet) can never wedge the pool.
    pub fn poll(&self, shard: usize, store: &PolicyStore) {
        let mut g = plock(&self.state);
        if g.observe(store) {
            g.acked[shard] = true;
            if g.all_live_acked() {
                g.flip();
            }
            self.changed.notify_all();
        }
    }

    /// Deregister an exiting shard (clean shutdown, backend error, or
    /// panic — called from the shard's down path) so remaining shards can
    /// still flip. Idempotent.
    pub fn leave(&self, shard: usize) {
        let mut g = plock(&self.state);
        if !g.live[shard] {
            return;
        }
        g.live[shard] = false;
        g.acked[shard] = false;
        if g.proposed.is_some() && g.live.iter().any(|&l| l) && g.all_live_acked() {
            g.flip();
        }
        self.changed.notify_all();
    }

    /// Re-register a revived shard (the supervisor respawns a panicked
    /// serve loop and rejoins it here before serving resumes). The shard
    /// comes back un-acked, so a proposal parked at the barrier now waits
    /// for its next dispatch boundary too — the revived shard can never
    /// observe a flip its peers haven't. Its dispatch counter survives
    /// the restart, keeping `--flip-schedule` boundaries monotonic.
    /// Idempotent.
    pub fn join(&self, shard: usize) {
        let mut g = plock(&self.state);
        if g.live[shard] {
            return;
        }
        g.live[shard] = true;
        g.acked[shard] = false;
        self.changed.notify_all();
    }

    /// Current pool epoch (0 before the first snapshot).
    pub fn epoch(&self) -> u64 {
        plock(&self.state).epoch
    }

    /// Completed barrier flips.
    pub fn flips(&self) -> u64 {
        plock(&self.state).flips
    }

    /// True while a publish is parked behind the barrier.
    pub fn flip_pending(&self) -> bool {
        plock(&self.state).proposed.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::normalizer::NormSnapshot;
    use std::thread;

    fn store_with(versions: usize) -> Arc<PolicyStore> {
        let s = Arc::new(PolicyStore::new());
        for v in 0..versions {
            s.publish(vec![v as f32], NormSnapshot::identity(1));
        }
        s
    }

    #[test]
    fn first_snapshot_adopts_without_barrier() {
        let store = store_with(1);
        let gate = EpochGate::new(2);
        let lease = gate.acquire(0, &store);
        assert_eq!(lease.epoch, 1);
        assert_eq!(lease.snapshot.version, 1);
        assert!(lease.flip_stall_us.is_none());
        // the other shard needs no handshake either
        let lease = gate.acquire(1, &store);
        assert_eq!(lease.epoch, 1);
        assert_eq!(gate.flips(), 0);
    }

    #[test]
    fn flip_blocks_until_every_live_shard_acks() {
        let store = store_with(1);
        let gate = Arc::new(EpochGate::new(2));
        gate.acquire(0, &store);
        gate.acquire(1, &store);
        store.publish(vec![9.0], NormSnapshot::identity(1));

        let (g2, s2) = (gate.clone(), store.clone());
        let h = thread::spawn(move || g2.acquire(0, &s2));
        thread::sleep(Duration::from_millis(40));
        // shard 1 has not acked: the pool must still be on epoch 1
        assert_eq!(gate.epoch(), 1);
        assert!(gate.flip_pending());

        // the last acker completes the flip and goes straight through
        let lease1 = gate.acquire(1, &store);
        assert_eq!(lease1.epoch, 2);
        assert_eq!(lease1.snapshot.version, 2);
        let lease0 = h.join().unwrap();
        assert_eq!(lease0.epoch, 2);
        assert_eq!(lease0.snapshot.version, 2);
        assert!(
            lease0.flip_stall_us.unwrap() > 0.0,
            "the parked shard must report its stall"
        );
        assert_eq!(gate.flips(), 1);
        assert!(!gate.flip_pending());
    }

    #[test]
    fn idle_poll_acks_and_completes_the_flip() {
        let store = store_with(1);
        let gate = Arc::new(EpochGate::new(2));
        gate.acquire(0, &store);
        gate.acquire(1, &store);
        store.publish(vec![1.0], NormSnapshot::identity(1));

        let (g2, s2) = (gate.clone(), store.clone());
        let h = thread::spawn(move || g2.acquire(0, &s2));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(gate.epoch(), 1);
        // shard 1 is idle (no pending slabs): its wait-loop poll must
        // stand in for a dispatch-boundary ack
        gate.poll(1, &store);
        let lease = h.join().unwrap();
        assert_eq!(lease.epoch, 2);
        assert_eq!(gate.epoch(), 2);
    }

    #[test]
    fn leave_releases_the_barrier() {
        let store = store_with(1);
        let gate = Arc::new(EpochGate::new(2));
        gate.acquire(0, &store);
        gate.acquire(1, &store);
        store.publish(vec![1.0], NormSnapshot::identity(1));

        let (g2, s2) = (gate.clone(), store.clone());
        let h = thread::spawn(move || g2.acquire(0, &s2));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(gate.epoch(), 1);
        // shard 1 dies (panic/backend error): it must not wedge the pool
        gate.leave(1);
        gate.leave(1); // idempotent
        let lease = h.join().unwrap();
        assert_eq!(lease.epoch, 2);
        assert_eq!(lease.snapshot.version, 2);
    }

    #[test]
    fn superseded_versions_flip_once_to_the_newest() {
        // two publishes land before the proposal cycle: single-slot
        // semantics skip the intermediate version, one flip total
        let store = store_with(1);
        let gate = EpochGate::new(2);
        gate.acquire(0, &store);
        gate.acquire(1, &store);
        store.publish(vec![1.0], NormSnapshot::identity(1)); // v2
        store.publish(vec![2.0], NormSnapshot::identity(1)); // v3
        gate.poll(0, &store);
        let lease = gate.acquire(1, &store);
        assert_eq!(lease.epoch, 2);
        assert_eq!(lease.snapshot.version, 3);
        assert_eq!(gate.flips(), 1);
    }

    #[test]
    fn join_after_leave_restores_barrier_participation() {
        let store = store_with(1);
        let gate = Arc::new(EpochGate::new(2));
        gate.acquire(0, &store);
        gate.acquire(1, &store);
        gate.leave(1);
        gate.join(1);
        gate.join(1); // idempotent
        store.publish(vec![1.0], NormSnapshot::identity(1));

        let (g2, s2) = (gate.clone(), store.clone());
        let h = thread::spawn(move || g2.acquire(0, &s2));
        thread::sleep(Duration::from_millis(40));
        // the revived shard is live again: the flip must wait for it
        assert_eq!(gate.epoch(), 1);
        assert!(gate.flip_pending());
        let lease = gate.acquire(1, &store);
        assert_eq!(lease.epoch, 2);
        assert_eq!(h.join().unwrap().epoch, 2);
    }

    #[test]
    fn flip_schedule_defers_the_ack_to_the_period_boundary() {
        // schedule 4: the shard acks only on dispatches 4, 8, 12, ...
        let store = store_with(1);
        let gate = EpochGate::with_schedule(1, 4);
        assert_eq!(gate.acquire(0, &store).epoch, 1); // dispatch 1: adopt
        store.publish(vec![1.0], NormSnapshot::identity(1));
        // dispatches 2 and 3 keep serving the old epoch past the publish
        assert_eq!(gate.acquire(0, &store).epoch, 1);
        assert_eq!(gate.acquire(0, &store).epoch, 1);
        assert!(gate.flip_pending());
        // dispatch 4 is the scheduled boundary: ack + flip
        let lease = gate.acquire(0, &store);
        assert_eq!(lease.epoch, 2);
        assert_eq!(lease.snapshot.version, 2);
        assert_eq!(gate.flips(), 1);
    }

    #[test]
    fn acquire_blocks_until_first_publish() {
        let store = Arc::new(PolicyStore::new());
        let gate = Arc::new(EpochGate::new(1));
        let (g2, s2) = (gate.clone(), store.clone());
        let h = thread::spawn(move || g2.acquire(0, &s2));
        thread::sleep(Duration::from_millis(20));
        store.publish(vec![0.0], NormSnapshot::identity(1));
        let lease = h.join().unwrap();
        assert_eq!(lease.epoch, 1);
        assert_eq!(lease.snapshot.version, 1);
    }
}
