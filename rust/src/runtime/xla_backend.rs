//! XLA/PJRT backend: loads the AOT HLO-text artifacts emitted by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//!
//! This is the production request path: Python is never loaded; the HLO
//! text (containing the lowered L2 model and L1 Pallas kernels) is parsed,
//! compiled once per worker thread at startup, and executed with `Literal`
//! buffers from then on. PJRT handles are not `Send`, so `XlaFactory`
//! (which is `Send + Sync`) carries only paths/metadata and each call to
//! `make_*` constructs a thread-local client + executables.

use super::{
    ActResult, ActorBackend, BackendFactory, DdpgActorBackend, DdpgBatch, DdpgLearnerBackend,
    DdpgTrainState, PpoLearnerBackend, PpoMinibatch, PpoTrainState,
};
use crate::nn::mlp::PpoStats;
use crate::runtime::artifacts::PresetMeta;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, ensure, Context, Result};
use std::path::Path;

/// Factory carrying artifact metadata; backends are built per thread.
pub struct XlaFactory {
    meta: PresetMeta,
}

impl XlaFactory {
    pub fn new(artifacts_dir: &str, preset: &str) -> Result<Self> {
        let meta = PresetMeta::load(artifacts_dir, preset)?;
        Ok(Self { meta })
    }

    pub fn meta(&self) -> &PresetMeta {
        &self.meta
    }

    /// Compile one `act`-family artifact into a fixed-batch PPO actor.
    fn make_actor_with(&self, artifact: &str, batch: usize) -> Result<Box<dyn ActorBackend>> {
        let client = xla::PjRtClient::cpu()?;
        let exe = compile(&client, self.meta.artifact(artifact)?)?;
        Ok(Box::new(XlaActor {
            client,
            exe,
            batch,
            obs_dim: self.meta.obs_dim,
            act_dim: self.meta.act_dim,
            params: ParamBufCache::new(),
        }))
    }

    /// Compile one `act_ddpg`-family artifact into a fixed-batch actor.
    fn make_ddpg_actor_with(
        &self,
        artifact: &str,
        batch: usize,
    ) -> Result<Box<dyn DdpgActorBackend>> {
        let client = xla::PjRtClient::cpu()?;
        let exe = compile(&client, self.meta.artifact(artifact)?)?;
        Ok(Box::new(XlaDdpgActor {
            client,
            exe,
            batch,
            obs_dim: self.meta.obs_dim,
            params: ParamBufCache::new(),
        }))
    }
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {path:?}"))
}

/// Execute and unpack the (return_tuple=True) result into literals.
fn run(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
    Ok(result.to_tuple()?)
}

fn lit_1d(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

fn lit_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    ensure!(data.len() == rows * cols, "bad 2d literal shape");
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::from(v)
}

fn to_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Device-resident parameter buffer cache (§Perf, EXPERIMENTS.md).
///
/// The sampler hot path calls `act` once per environment step but the
/// parameter vector only changes when the policy store publishes a new
/// version, so re-staging the (tens of KB) flat vector as a fresh Literal
/// every call dominated inference latency. We cache the params as a
/// `PjRtBuffer` keyed by a cheap fingerprint (pointer + length + sampled
/// values) and only re-upload on change.
struct ParamBufCache {
    key: u128,
    buf: Option<xla::PjRtBuffer>,
}

impl ParamBufCache {
    fn new() -> Self {
        Self { key: 0, buf: None }
    }

    fn fingerprint(data: &[f32]) -> u128 {
        let mut h = data.as_ptr() as u128;
        h = h.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(data.len() as u128);
        // sample a few values so a reused allocation with new content
        // cannot alias the old key
        for &i in &[0usize, data.len() / 2, data.len().saturating_sub(1)] {
            if let Some(v) = data.get(i) {
                h = h
                    .wrapping_mul(0x100000001b3)
                    .wrapping_add(v.to_bits() as u128);
            }
        }
        h | 1 // never 0 (the empty-cache sentinel)
    }

    fn get(
        &mut self,
        client: &xla::PjRtClient,
        data: &[f32],
    ) -> Result<&xla::PjRtBuffer> {
        let key = Self::fingerprint(data);
        if self.key != key || self.buf.is_none() {
            self.buf = Some(client.buffer_from_host_buffer(data, &[data.len()], None)?);
            self.key = key;
        }
        Ok(self.buf.as_ref().unwrap())
    }
}

fn scalar_of(l: &xla::Literal) -> Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}

impl BackendFactory for XlaFactory {
    fn obs_dim(&self) -> usize {
        self.meta.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.meta.act_dim
    }

    fn ppo_param_count(&self) -> usize {
        self.meta.param_count
    }

    fn init_ppo_params(&self, seed: u64) -> Vec<f32> {
        self.meta.layout.init_flat(&mut Pcg64::new(seed))
    }

    fn init_ddpg_params(&self, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let d = self.meta.ddpg.as_ref().expect("preset has no DDPG artifacts");
        let mut rng = Pcg64::new(seed);
        (
            d.actor_layout.init_flat(&mut rng),
            d.critic_layout.init_flat(&mut rng),
        )
    }

    fn make_actor(&self) -> Result<Box<dyn ActorBackend>> {
        self.make_actor_with("act", self.meta.act_batch)
    }

    fn make_ppo_learner(&self) -> Result<Box<dyn PpoLearnerBackend>> {
        let client = xla::PjRtClient::cpu()?;
        let train = compile(&client, self.meta.artifact("train_ppo")?)?;
        let gae = compile(&client, self.meta.artifact("gae")?)?;
        let grad = if self.meta.has_artifact("grad_ppo") {
            Some(compile(&client, self.meta.artifact("grad_ppo")?)?)
        } else {
            None
        };
        let apply = if self.meta.has_artifact("apply_grads") {
            Some(compile(&client, self.meta.artifact("apply_grads")?)?)
        } else {
            None
        };
        Ok(Box::new(XlaPpoLearner {
            _client: client,
            train,
            gae,
            grad,
            apply,
            minibatch: self.meta.minibatch,
            horizon: self.meta.horizon,
            obs_dim: self.meta.obs_dim,
            act_dim: self.meta.act_dim,
            param_count: self.meta.param_count,
        }))
    }

    /// XLA `act` executables are shape-specialized at AOT time; aot.py
    /// emits one per batch size in `Preset.act_batches`, so any
    /// `envs_per_sampler` with a matching artifact gets a padding-free
    /// forward. Row counts without an exact artifact run inside the
    /// smallest emitted batch that fits (rows `batch..B` are zero padding
    /// whose outputs the sampler ignores).
    fn make_actor_batched(&self, batch: usize) -> Result<Box<dyn ActorBackend>> {
        ensure!(batch > 0, "make_actor_batched: batch must be >= 1");
        let (artifact, b) = self.meta.act_artifact_for("act", batch)?;
        if b > batch {
            crate::log_debug!(
                "xla actor: {batch} real rows in {artifact} (batch {b}, {} padded rows per call)",
                b - batch
            );
        }
        self.make_actor_with(&artifact, b)
    }

    fn make_ddpg_actor_batched(&self, batch: usize) -> Result<Box<dyn DdpgActorBackend>> {
        ensure!(batch > 0, "make_ddpg_actor_batched: batch must be >= 1");
        let (artifact, b) = self.meta.act_artifact_for("act_ddpg", batch)?;
        self.make_ddpg_actor_with(&artifact, b)
    }

    /// Fleet-slice actor for one shared-inference shard. Compiles EVERY
    /// emitted act bucket up to the smallest batch that holds `max_rows`
    /// (the shard's workers x M) and reports a flexible batch (0) to the
    /// server, so each dispatch runs in the smallest bucket that fits
    /// its REAL row count — a straggler-cut partial batch pads to the
    /// nearest bucket, not the full shard capacity. When no emitted
    /// artifact is large enough, the error says how many rows the
    /// artifacts CAN hold so the user can raise `--infer-shards`
    /// instead of re-running aot.py.
    fn make_actor_shared(&self, max_rows: usize) -> Result<Box<dyn ActorBackend>> {
        ensure!(max_rows > 0, "make_actor_shared: max_rows must be >= 1");
        let named = self.meta.act_buckets_for("act", max_rows).with_context(|| {
            format!(
                "shard needs {max_rows} rows but the largest act artifact holds {} — \
                 raise --infer-shards so each shard's workers*M fits",
                self.meta.max_act_rows("act")
            )
        })?;
        let client = xla::PjRtClient::cpu()?;
        let mut buckets = Vec::with_capacity(named.len());
        for (name, b) in &named {
            buckets.push((*b, compile(&client, self.meta.artifact(name)?)?));
        }
        let cap = buckets.last().map_or(0, |(b, _)| *b);
        let (o, a) = (self.meta.obs_dim, self.meta.act_dim);
        Ok(Box::new(XlaBucketedActor {
            client,
            buckets,
            obs_dim: o,
            act_dim: a,
            params: ParamBufCache::new(),
            obs_pad: vec![0.0; cap * o],
            noise_pad: vec![0.0; cap * a],
        }))
    }

    fn make_ddpg_actor_shared(&self, max_rows: usize) -> Result<Box<dyn DdpgActorBackend>> {
        ensure!(max_rows > 0, "make_ddpg_actor_shared: max_rows must be >= 1");
        let named = self
            .meta
            .act_buckets_for("act_ddpg", max_rows)
            .with_context(|| {
                format!(
                    "shard needs {max_rows} rows but the largest act_ddpg artifact holds {} — \
                     raise --infer-shards so each shard's workers*M fits",
                    self.meta.max_act_rows("act_ddpg")
                )
            })?;
        let client = xla::PjRtClient::cpu()?;
        let mut buckets = Vec::with_capacity(named.len());
        for (name, b) in &named {
            buckets.push((*b, compile(&client, self.meta.artifact(name)?)?));
        }
        let cap = buckets.last().map_or(0, |(b, _)| *b);
        let o = self.meta.obs_dim;
        Ok(Box::new(XlaBucketedDdpgActor {
            client,
            buckets,
            obs_dim: o,
            act_dim: self.meta.act_dim,
            params: ParamBufCache::new(),
            obs_pad: vec![0.0; cap * o],
        }))
    }

    fn make_ddpg_actor(&self) -> Result<Box<dyn DdpgActorBackend>> {
        self.make_ddpg_actor_with("act_ddpg", self.meta.act_batch)
    }

    fn make_ddpg_learner(&self) -> Result<Box<dyn DdpgLearnerBackend>> {
        let d = self
            .meta
            .ddpg
            .as_ref()
            .ok_or_else(|| anyhow!("preset {} has no DDPG artifacts", self.meta.preset))?;
        let client = xla::PjRtClient::cpu()?;
        let exe = compile(&client, self.meta.artifact("train_ddpg")?)?;
        Ok(Box::new(XlaDdpgLearner {
            _client: client,
            exe,
            batch: d.batch,
            obs_dim: self.meta.obs_dim,
            act_dim: self.meta.act_dim,
        }))
    }
}

// ----------------------------------------------------------------- actor

struct XlaActor {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    obs_dim: usize,
    act_dim: usize,
    params: ParamBufCache,
}

impl ActorBackend for XlaActor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.act_dim
    }

    fn act(&mut self, flat: &[f32], obs: &[f32], noise: &[f32]) -> Result<ActResult> {
        ensure!(
            obs.len() == self.batch * self.obs_dim,
            "act: obs len {} != B{} * O{}",
            obs.len(),
            self.batch,
            self.obs_dim
        );
        let param_buf = self.params.get(&self.client, flat)?;
        let obs_buf =
            self.client
                .buffer_from_host_buffer(obs, &[self.batch, self.obs_dim], None)?;
        let noise_buf =
            self.client
                .buffer_from_host_buffer(noise, &[self.batch, self.act_dim], None)?;
        let result =
            self.exe.execute_b::<&xla::PjRtBuffer>(&[param_buf, &obs_buf, &noise_buf])?[0][0]
                .to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 4, "act artifact returned {} outputs", outs.len());
        Ok(ActResult {
            action: to_vec(&outs[0])?,
            logp: to_vec(&outs[1])?,
            value: to_vec(&outs[2])?,
            mean: to_vec(&outs[3])?,
        })
    }
}

/// Shared-inference actor over a ladder of shape-specialized
/// executables. Reports `batch() == 0` (flexible) so the server
/// dispatches exactly the real rows; each call runs in the smallest
/// compiled bucket that fits, zero-padding only the bucket remainder
/// and truncating the outputs back to the real row count.
struct XlaBucketedActor {
    client: xla::PjRtClient,
    /// Ascending `(batch, executable)`; smallest fit wins per call.
    buckets: Vec<(usize, xla::PjRtLoadedExecutable)>,
    obs_dim: usize,
    act_dim: usize,
    params: ParamBufCache,
    /// Scratch padding buffers sized for the largest bucket.
    obs_pad: Vec<f32>,
    noise_pad: Vec<f32>,
}

impl ActorBackend for XlaBucketedActor {
    fn batch(&self) -> usize {
        0 // flexible: the server sends real rows, padding happens here
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.act_dim
    }

    fn act(&mut self, flat: &[f32], obs: &[f32], noise: &[f32]) -> Result<ActResult> {
        let (o, a) = (self.obs_dim, self.act_dim);
        ensure!(
            !obs.is_empty() && obs.len() % o == 0,
            "act: bad obs len {} for O{o}",
            obs.len()
        );
        let rows = obs.len() / o;
        ensure!(
            noise.len() == rows * a,
            "act: noise len {} != rows {rows} * A{a}",
            noise.len()
        );
        let idx = self
            .buckets
            .iter()
            .position(|(b, _)| *b >= rows)
            .ok_or_else(|| {
                anyhow!(
                    "no act bucket holds {rows} rows (largest {})",
                    self.buckets.last().map_or(0, |(b, _)| *b)
                )
            })?;
        let b = self.buckets[idx].0;
        let (obs_in, noise_in): (&[f32], &[f32]) = if b == rows {
            (obs, noise)
        } else {
            self.obs_pad[..rows * o].copy_from_slice(obs);
            self.obs_pad[rows * o..b * o].iter_mut().for_each(|z| *z = 0.0);
            self.noise_pad[..rows * a].copy_from_slice(noise);
            self.noise_pad[rows * a..b * a].iter_mut().for_each(|z| *z = 0.0);
            (&self.obs_pad[..b * o], &self.noise_pad[..b * a])
        };
        let param_buf = self.params.get(&self.client, flat)?;
        let obs_buf = self.client.buffer_from_host_buffer(obs_in, &[b, o], None)?;
        let noise_buf = self.client.buffer_from_host_buffer(noise_in, &[b, a], None)?;
        let exe = &self.buckets[idx].1;
        let result = exe.execute_b::<&xla::PjRtBuffer>(&[param_buf, &obs_buf, &noise_buf])?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 4, "act artifact returned {} outputs", outs.len());
        let mut r = ActResult {
            action: to_vec(&outs[0])?,
            logp: to_vec(&outs[1])?,
            value: to_vec(&outs[2])?,
            mean: to_vec(&outs[3])?,
        };
        // drop the bucket's padding rows so callers see exactly `rows`
        r.action.truncate(rows * a);
        r.logp.truncate(rows);
        r.value.truncate(rows);
        r.mean.truncate(rows * a);
        Ok(r)
    }
}

/// DDPG/TD3 variant of [`XlaBucketedActor`] (deterministic actor head,
/// no noise lanes).
struct XlaBucketedDdpgActor {
    client: xla::PjRtClient,
    buckets: Vec<(usize, xla::PjRtLoadedExecutable)>,
    obs_dim: usize,
    act_dim: usize,
    params: ParamBufCache,
    obs_pad: Vec<f32>,
}

impl DdpgActorBackend for XlaBucketedDdpgActor {
    fn batch(&self) -> usize {
        0
    }

    fn act(&mut self, actor: &[f32], obs: &[f32]) -> Result<Vec<f32>> {
        let o = self.obs_dim;
        ensure!(
            !obs.is_empty() && obs.len() % o == 0,
            "act_ddpg: bad obs len {} for O{o}",
            obs.len()
        );
        let rows = obs.len() / o;
        let idx = self
            .buckets
            .iter()
            .position(|(b, _)| *b >= rows)
            .ok_or_else(|| {
                anyhow!(
                    "no act_ddpg bucket holds {rows} rows (largest {})",
                    self.buckets.last().map_or(0, |(b, _)| *b)
                )
            })?;
        let b = self.buckets[idx].0;
        let obs_in: &[f32] = if b == rows {
            obs
        } else {
            self.obs_pad[..rows * o].copy_from_slice(obs);
            self.obs_pad[rows * o..b * o].iter_mut().for_each(|z| *z = 0.0);
            &self.obs_pad[..b * o]
        };
        let param_buf = self.params.get(&self.client, actor)?;
        let obs_buf = self.client.buffer_from_host_buffer(obs_in, &[b, o], None)?;
        let exe = &self.buckets[idx].1;
        let result = exe.execute_b::<&xla::PjRtBuffer>(&[param_buf, &obs_buf])?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 1, "act_ddpg returned {} outputs", outs.len());
        let mut action = to_vec(&outs[0])?;
        action.truncate(rows * self.act_dim);
        Ok(action)
    }
}

// --------------------------------------------------------------- learner

struct XlaPpoLearner {
    _client: xla::PjRtClient,
    train: xla::PjRtLoadedExecutable,
    gae: xla::PjRtLoadedExecutable,
    grad: Option<xla::PjRtLoadedExecutable>,
    apply: Option<xla::PjRtLoadedExecutable>,
    minibatch: usize,
    horizon: usize,
    obs_dim: usize,
    act_dim: usize,
    param_count: usize,
}

impl PpoLearnerBackend for XlaPpoLearner {
    fn minibatch_size(&self) -> usize {
        self.minibatch
    }

    fn train_step(
        &mut self,
        state: &mut PpoTrainState,
        lr: f32,
        mb: &PpoMinibatch<'_>,
    ) -> Result<PpoStats> {
        let m = self.minibatch;
        ensure!(state.flat.len() == self.param_count, "bad param count");
        ensure!(mb.old_logp.len() == m, "minibatch must be padded to {m}");
        state.t += 1;
        let args = [
            lit_1d(&state.flat),
            lit_1d(&state.m),
            lit_1d(&state.v),
            lit_scalar(state.t as f32),
            lit_scalar(lr),
            lit_2d(mb.obs, m, self.obs_dim)?,
            lit_2d(mb.act, m, self.act_dim)?,
            lit_1d(mb.old_logp),
            lit_1d(mb.adv),
            lit_1d(mb.ret),
            lit_1d(mb.mask),
        ];
        let outs = run(&self.train, &args)?;
        ensure!(outs.len() == 9, "train_ppo returned {} outputs", outs.len());
        state.flat = to_vec(&outs[0])?;
        state.m = to_vec(&outs[1])?;
        state.v = to_vec(&outs[2])?;
        Ok(PpoStats {
            total: scalar_of(&outs[3])?,
            pi_loss: scalar_of(&outs[4])?,
            v_loss: scalar_of(&outs[5])?,
            entropy: scalar_of(&outs[6])?,
            approx_kl: scalar_of(&outs[7])?,
            clip_frac: scalar_of(&outs[8])?,
        })
    }

    fn grad(&mut self, flat: &[f32], mb: &PpoMinibatch<'_>) -> Result<(Vec<f32>, f32, f32)> {
        let exe = self
            .grad
            .as_ref()
            .ok_or_else(|| anyhow!("grad_ppo artifact not emitted for this preset"))?;
        let m = self.minibatch;
        let args = [
            lit_1d(flat),
            lit_2d(mb.obs, m, self.obs_dim)?,
            lit_2d(mb.act, m, self.act_dim)?,
            lit_1d(mb.old_logp),
            lit_1d(mb.adv),
            lit_1d(mb.ret),
            lit_1d(mb.mask),
        ];
        let outs = run(exe, &args)?;
        ensure!(outs.len() == 3, "grad_ppo returned {} outputs", outs.len());
        Ok((to_vec(&outs[0])?, scalar_of(&outs[1])?, scalar_of(&outs[2])?))
    }

    fn apply_grads(&mut self, state: &mut PpoTrainState, grads: &[f32], lr: f32) -> Result<()> {
        let exe = self
            .apply
            .as_ref()
            .ok_or_else(|| anyhow!("apply_grads artifact not emitted for this preset"))?;
        state.t += 1;
        let args = [
            lit_1d(&state.flat),
            lit_1d(&state.m),
            lit_1d(&state.v),
            lit_1d(grads),
            lit_scalar(state.t as f32),
            lit_scalar(lr),
        ];
        let outs = run(exe, &args)?;
        ensure!(outs.len() == 3, "apply_grads returned {} outputs", outs.len());
        state.flat = to_vec(&outs[0])?;
        state.m = to_vec(&outs[1])?;
        state.v = to_vec(&outs[2])?;
        Ok(())
    }

    /// GAE via the L1 Pallas gae_scan artifact. Ragged inputs are padded to
    /// the preset horizon; the padding contributes exactly zero because
    /// `cont` is zero there (see kernels/gae.py).
    fn gae(&mut self, rew: &[f32], val: &[f32], cont: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let t_len = rew.len();
        ensure!(val.len() == t_len + 1, "val needs bootstrap entry");
        ensure!(
            t_len <= self.horizon,
            "trajectory length {t_len} exceeds artifact horizon {}",
            self.horizon
        );
        let h = self.horizon;
        let mut rew_p = vec![0.0f32; h];
        rew_p[..t_len].copy_from_slice(rew);
        let mut cont_p = vec![0.0f32; h];
        cont_p[..t_len].copy_from_slice(cont);
        let mut val_p = vec![0.0f32; h + 1];
        val_p[..=t_len].copy_from_slice(val);
        if t_len < h {
            // Make the first padded step's delta exactly zero:
            //   delta[t_len] = rew[t_len] + γ·cont[t_len]·val[t_len+1] - val[t_len]
            // cont[t_len] = 0 and rew[t_len] = val[t_len] (the bootstrap)
            // gives delta = 0, so adv[t_len] = 0 and the carry into the
            // last real step is clean while delta[t_len-1] still sees the
            // bootstrap in val[t_len].
            rew_p[t_len] = val[t_len];
        }
        let args = [lit_1d(&rew_p), lit_1d(&val_p), lit_1d(&cont_p)];
        let outs = run(&self.gae, &args)?;
        ensure!(outs.len() == 2, "gae returned {} outputs", outs.len());
        let mut adv = to_vec(&outs[0])?;
        let mut ret = to_vec(&outs[1])?;
        adv.truncate(t_len);
        ret.truncate(t_len);
        Ok((adv, ret))
    }
}

// ------------------------------------------------------------------ DDPG

struct XlaDdpgActor {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    obs_dim: usize,
    params: ParamBufCache,
}

impl DdpgActorBackend for XlaDdpgActor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn act(&mut self, actor: &[f32], obs: &[f32]) -> Result<Vec<f32>> {
        let param_buf = self.params.get(&self.client, actor)?;
        let obs_buf =
            self.client
                .buffer_from_host_buffer(obs, &[self.batch, self.obs_dim], None)?;
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&[param_buf, &obs_buf])?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 1, "act_ddpg returned {} outputs", outs.len());
        to_vec(&outs[0])
    }
}

struct XlaDdpgLearner {
    _client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    obs_dim: usize,
    act_dim: usize,
}

impl DdpgLearnerBackend for XlaDdpgLearner {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn train_step(
        &mut self,
        st: &mut DdpgTrainState,
        lr_actor: f32,
        lr_critic: f32,
        batch: &DdpgBatch<'_>,
    ) -> Result<(f32, f32)> {
        let b = self.batch;
        ensure!(batch.rew.len() == b, "ddpg batch must be exactly {b}");
        st.t += 1;
        let args = [
            lit_1d(&st.actor),
            lit_1d(&st.critic),
            lit_1d(&st.targ_actor),
            lit_1d(&st.targ_critic),
            lit_1d(&st.am),
            lit_1d(&st.av),
            lit_1d(&st.cm),
            lit_1d(&st.cv),
            lit_scalar(st.t as f32),
            lit_scalar(lr_actor),
            lit_scalar(lr_critic),
            lit_2d(batch.obs, b, self.obs_dim)?,
            lit_2d(batch.act, b, self.act_dim)?,
            lit_1d(batch.rew),
            lit_2d(batch.next_obs, b, self.obs_dim)?,
            lit_1d(batch.done),
        ];
        let outs = run(&self.exe, &args)?;
        ensure!(outs.len() == 10, "train_ddpg returned {} outputs", outs.len());
        st.actor = to_vec(&outs[0])?;
        st.critic = to_vec(&outs[1])?;
        st.targ_actor = to_vec(&outs[2])?;
        st.targ_critic = to_vec(&outs[3])?;
        st.am = to_vec(&outs[4])?;
        st.av = to_vec(&outs[5])?;
        st.cm = to_vec(&outs[6])?;
        st.cv = to_vec(&outs[7])?;
        Ok((scalar_of(&outs[8])?, scalar_of(&outs[9])?))
    }
}
