//! Sharded shared-inference pool: S server threads, each owning one
//! fleet-slice batched forward, serve all N sampler workers
//! (`--inference-mode shared`, `--infer-shards S`).
//!
//! PR 1 vectorized each worker over M lockstep envs, but every worker
//! still ran its own private backend: N small forwards per sim tick
//! fleet-wide. PR 2 centralized policy evaluation the way SEED-style
//! systems and Spreeze do — one server thread owning an `N * M`-row
//! actor — and PR 3 shards that server so the design keeps scaling once a
//! single mega-batch forward saturates a core at large `N * M`.
//!
//! # Request lifecycle
//!
//! 1. A worker calls [`ActorClient::act`] with its raw M-row obs slab
//!    (plus per-row N(0,1) noise for PPO; empty noise for DDPG). The
//!    slab is copied into the client's reusable [`SlabBuffers`] and
//!    pushed onto the shard's MPSC queue; the worker blocks on its
//!    per-client completion slot (SPSC: the server fills it, exactly one
//!    client waits).
//! 2. The shard's serve loop coalesces pending slabs into one batch and
//!    dispatches — running ONE forward over all rows — when every
//!    registered client has a slab pending (the fleet slice is in phase:
//!    one forward per sim tick) or when the [`WaitPolicy`] cut fires, so
//!    a straggler worker (env reset, episode bookkeeping, queue
//!    backpressure, sync-mode parking) never stalls its shard.
//! 3. The server takes one policy observation per dispatch, so every row
//!    in a forward is evaluated under the same parameter version, and
//!    each [`ActResponse`] carries the snapshot used plus the pool epoch
//!    (the one-version-per-forward guarantee). A worker that sees the
//!    epoch move cuts its in-progress chunks before appending the new
//!    tick (see `coordinator::sampler`), preserving the
//!    one-policy-version-per-chunk invariant with zero worker-side store
//!    polling. Under the default pool-wide epoch gate
//!    ([`crate::runtime::epoch::EpochGate`], `--infer-epoch pool`) all S
//!    shards flip to a newly published snapshot on the same dispatch
//!    boundary — no shard dispatches under the new version while another
//!    still serves the old one. `--infer-epoch shard` restores the PR 3
//!    behavior of independent per-shard store polling (each worker's
//!    streams stay single-version regardless).
//! 4. Results are scattered back into each request's [`SlabBuffers`]
//!    (actions, logp, values, means, and the server-normalized obs rows)
//!    and handed to the blocked client. Dropping the response returns the
//!    buffers to the client's spare slot for the next tick.
//!
//! # Shard assignment invariant
//!
//! [`InferencePool`] spawns `S` shards and statically assigns worker `w`
//! to shard `w % S` ([`InferencePool::client`]). The assignment is
//! deterministic and never rebalanced, each shard's actor is sized to
//! exactly the rows of its assigned workers, and the MLP forward is
//! row-independent — so under a fixed policy version, per-env chunk
//! streams are bitwise identical across any shard count (and across
//! shared vs local mode). With the pool epoch gate this extends *across*
//! policy version flips whenever the flip tick is itself deterministic
//! (e.g. sync mode's per-version sample budget). Tested at N=4: S=1 vs
//! S=2 under a frozen policy, and local vs S∈{1,2,4} across two mid-run
//! publishes, in `coordinator::sampler`.
//!
//! # Failure containment
//!
//! A serve thread never strands its fleet: a sentinel guard on every
//! serve entry point marks the shard down and fails all pending and
//! future requests on ANY exit — clean shutdown, backend construction
//! error, forward error, or panic (including panics inside backend
//! construction). Blocked workers observe the failure within one probe
//! interval and terminate with a logged error instead of deadlocking on
//! their completion slots; the shard also leaves the epoch gate so the
//! surviving shards can still flip.
//!
//! # Straggler-cut policy ([`WaitPolicy`])
//!
//! * `Fixed(d)` — dispatch a partial batch once `d` has elapsed since the
//!   first pending slab (the PR 2 knob, `--infer-wait fixed:<us>`).
//! * `Adaptive` (default) — per shard, track an EWMA and mean absolute
//!   deviation of the *intra-window* client inter-arrival gaps and cut
//!   when the queue has been quiet for `2*EWMA + 4*MAD` microseconds
//!   (clamped to [10us, 10ms]): once the expected wait for the next slab
//!   exceeds twice the typical gap, the marginal batch fill no longer
//!   pays for the added latency of every row already on board. A hard cap
//!   of 10ms from the first arrival bounds the wait even while the
//!   estimator is still learning.
//!
//! # Allocation-free steady state
//!
//! Every buffer on the per-tick path is owned and reused: clients recycle
//! their [`SlabBuffers`] through the completion slot, the server packs
//! into a pre-sized mega-batch buffer and swaps (never reallocates) the
//! pending-request vector. A shard-level counter
//! ([`InferenceReport::hot_allocs`](crate::coordinator::metrics::InferenceReport))
//! increments on every hot-path buffer growth; after warmup it must stay
//! flat — asserted in this module's tests and recorded by
//! `cargo bench --bench micro`. (The policy backend's internal
//! temporaries are its own concern and are not part of this guarantee.)
//!
//! Threading: backends are not `Send` on the XLA path, so
//! [`InferenceServer::serve_ppo`] / [`serve_ddpg`](InferenceServer::serve_ddpg)
//! build the backend on the calling thread (the orchestrator spawns one
//! thread per shard) and everything else communicates through
//! `Mutex`/`Condvar` queues.

use crate::algo::api::Algorithm;
use crate::coordinator::metrics::InferenceReport;
use crate::coordinator::policy_store::{PolicySnapshot, PolicyStore};
use crate::runtime::epoch::{EpochGate, EpochMode};
use crate::runtime::{BackendFactory, ServerActor};
use crate::util::fault::FaultCell;
use crate::util::{cv_wait, plock};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// When a shard dispatches a partial batch instead of waiting for the
/// remaining workers (see the module docs for the full policy).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WaitPolicy {
    /// Dispatch after this long from the first pending slab.
    Fixed(Duration),
    /// Dispatch when the arrival stream goes quiet for an adaptive cut
    /// derived from the observed inter-arrival gaps ([`AdaptiveWait`]).
    Adaptive,
}

/// Floor of the adaptive cut, microseconds (never dispatch more eagerly
/// than this on a momentarily quiet queue).
pub const ADAPTIVE_MIN_CUT_US: f64 = 10.0;
/// Ceiling of the adaptive cut AND the hard cap on total window wait,
/// microseconds — a parked worker can stall its shard at most this long.
pub const ADAPTIVE_MAX_CUT_US: f64 = 10_000.0;
/// Cut used before the estimator has observed any gap.
pub const ADAPTIVE_DEFAULT_CUT_US: f64 = 200.0;

/// Online estimator of client inter-arrival gaps driving the adaptive
/// straggler cut: an exponentially-weighted mean plus an EWMA of the
/// absolute deviation (a cheap, outlier-tolerant spread proxy — tracking
/// mean + 4 deviations lands near the P95 tail the ROADMAP asked for
/// without keeping a quantile sketch on the hot path).
#[derive(Debug, Clone)]
pub struct AdaptiveWait {
    gap_ewma_us: f64,
    gap_dev_us: f64,
    primed: bool,
}

/// EWMA smoothing factor: ~the last few dozen gaps dominate, so the cut
/// re-converges within one chunk window after a phase change.
const ADAPTIVE_ALPHA: f64 = 0.08;

impl AdaptiveWait {
    pub fn new() -> AdaptiveWait {
        AdaptiveWait {
            gap_ewma_us: 0.0,
            gap_dev_us: 0.0,
            primed: false,
        }
    }

    /// Record one intra-window inter-arrival gap (microseconds).
    pub fn observe(&mut self, gap_us: f64) {
        if !gap_us.is_finite() || gap_us < 0.0 {
            return;
        }
        if !self.primed {
            self.gap_ewma_us = gap_us;
            self.gap_dev_us = gap_us * 0.5;
            self.primed = true;
            return;
        }
        let dev = (gap_us - self.gap_ewma_us).abs();
        self.gap_dev_us += ADAPTIVE_ALPHA * (dev - self.gap_dev_us);
        self.gap_ewma_us += ADAPTIVE_ALPHA * (gap_us - self.gap_ewma_us);
    }

    /// Current cut budget in microseconds: dispatch a partial batch once
    /// the queue has been quiet this long. `2*EWMA + 4*MAD`, clamped
    /// between [`ADAPTIVE_MIN_CUT_US`] and [`ADAPTIVE_MAX_CUT_US`];
    /// before the first observation, [`ADAPTIVE_DEFAULT_CUT_US`].
    pub fn cut_us(&self) -> f64 {
        if !self.primed {
            return ADAPTIVE_DEFAULT_CUT_US;
        }
        (2.0 * self.gap_ewma_us + 4.0 * self.gap_dev_us)
            .clamp(ADAPTIVE_MIN_CUT_US, ADAPTIVE_MAX_CUT_US)
    }
}

impl Default for AdaptiveWait {
    fn default() -> Self {
        Self::new()
    }
}

/// Static per-shard configuration (derived from `TrainConfig` by
/// [`InferencePool::new`]; [`InferenceServerCfg::single`] builds a
/// standalone one-shard config for tests and benches).
#[derive(Debug, Clone)]
pub struct InferenceServerCfg {
    /// Straggler-cut policy for partial batches.
    pub wait: WaitPolicy,
    /// This shard's capacity in rows (assigned workers x M envs each).
    pub fleet_rows: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    /// 0-based shard index, prefixed onto this shard's error logs.
    pub shard_id: usize,
    /// Row count sizing the report's dispatch histogram buckets — the
    /// max shard capacity pool-wide, so per-shard reports stay mergeable.
    pub hist_rows: usize,
}

impl InferenceServerCfg {
    /// A standalone single-shard config (shard 0, histogram buckets sized
    /// to its own capacity).
    pub fn single(
        wait: WaitPolicy,
        fleet_rows: usize,
        obs_dim: usize,
        act_dim: usize,
    ) -> InferenceServerCfg {
        InferenceServerCfg {
            wait,
            fleet_rows,
            obs_dim,
            act_dim,
            shard_id: 0,
            hist_rows: fleet_rows,
        }
    }
}

/// Owned, reusable request/response buffers for one worker's slab. The
/// client fills `obs`/`noise` on submit; the server overwrites `obs` with
/// the normalized rows and fills `action`/`logp`/`value`/`mean` on reply.
/// Recycled through the completion slot, so the steady-state tick
/// performs zero allocations (see the module docs).
#[derive(Debug, Default)]
pub struct SlabBuffers {
    /// Request: raw obs rows; after reply: the same rows normalized under
    /// the dispatch snapshot ([rows * obs_dim]).
    pub obs: Vec<f32>,
    /// [rows * act_dim] N(0,1) draws (PPO) or empty (DDPG deterministic).
    pub noise: Vec<f32>,
    /// Reply: [rows * act_dim] sampled actions.
    pub action: Vec<f32>,
    /// Reply: [rows] log-probabilities (zero for DDPG).
    pub logp: Vec<f32>,
    /// Reply: [rows] value estimates (zero for DDPG).
    pub value: Vec<f32>,
    /// Reply: [rows * act_dim] distribution means (== action for DDPG).
    pub mean: Vec<f32>,
}

/// Resize `v` to `len`, counting a hot-path allocation event when the
/// resize has to grow the backing storage. Steady state: capacity already
/// suffices, no event, no allocation.
fn ensure_len(v: &mut Vec<f32>, len: usize, allocs: &AtomicU64) {
    if v.capacity() < len {
        allocs.fetch_add(1, Ordering::Relaxed);
    }
    v.resize(len, 0.0);
}

/// What the server hands back for one slab (delivered through the
/// completion slot, wrapped into an [`ActResponse`] by the client).
struct Reply {
    bufs: SlabBuffers,
    rows: usize,
    snapshot: Arc<PolicySnapshot>,
    epoch: u64,
    server_busy_secs: f64,
}

/// One policy evaluation answer for a single worker's slab. Borrows
/// nothing: it owns the recycled [`SlabBuffers`], and dropping it returns
/// them to the client's spare slot — so keep it alive only for the tick
/// that consumes it.
pub struct ActResponse {
    bufs: Option<SlabBuffers>,
    rows: usize,
    obs_dim: usize,
    act_dim: usize,
    home: Arc<ReplySlot>,
    /// The policy snapshot this forward used (same for every row of the
    /// dispatch — the one-version-per-forward guarantee).
    pub snapshot: Arc<PolicySnapshot>,
    /// Pool epoch of the dispatch. Under `--infer-epoch pool` this moves
    /// in lockstep across every shard (all S flip on the same dispatch
    /// boundary), so workers drive their chunk version-cuts off it; 0
    /// when the shard runs gateless (`--infer-epoch shard`, standalone
    /// servers), where the snapshot version alone drives cuts.
    pub epoch: u64,
    /// This slab's row-proportional share of the server's CPU time for
    /// the dispatch (normalize + forward). Workers fold it into their
    /// busy-time accounting so the virtual-core rollout timing model
    /// stays honest when inference runs off-thread.
    pub server_busy_secs: f64,
}

impl ActResponse {
    fn bufs(&self) -> &SlabBuffers {
        self.bufs.as_ref().expect("buffers present until drop")
    }

    /// This worker's sampled actions ([rows * act_dim]).
    pub fn action(&self) -> &[f32] {
        &self.bufs().action[..self.rows * self.act_dim]
    }

    /// Per-row log π(a|s) (zero-filled for DDPG).
    pub fn logp(&self) -> &[f32] {
        &self.bufs().logp[..self.rows]
    }

    /// Per-row value estimates (zero-filled for DDPG).
    pub fn value(&self) -> &[f32] {
        &self.bufs().value[..self.rows]
    }

    /// Per-row distribution means (the deterministic action).
    pub fn mean(&self) -> &[f32] {
        &self.bufs().mean[..self.rows * self.act_dim]
    }

    /// The worker's obs normalized under [`ActResponse::snapshot`]
    /// ([rows * obs_dim]) — exactly what the policy saw.
    pub fn norm_obs(&self) -> &[f32] {
        &self.bufs().obs[..self.rows * self.obs_dim]
    }
}

impl Drop for ActResponse {
    fn drop(&mut self) {
        // recycle the buffers into the client's spare pool (poison-
        // tolerant: a panicking worker must not lose the run). A Vec, not
        // a single slot: a worker may hold its tick response across the
        // bootstrap call, so up to two buffer sets cycle per client.
        if let Some(b) = self.bufs.take() {
            plock(&self.home.spare).push(b);
        }
    }
}

/// Completion slot: SPSC — the server fills it, exactly one client waits.
/// Also hosts the client's spare buffer sets between ticks.
struct ReplySlot {
    cell: Mutex<Option<Result<Reply, String>>>,
    ready: Condvar,
    spare: Mutex<Vec<SlabBuffers>>,
}

struct PendingReq {
    rows: usize,
    bufs: SlabBuffers,
    enqueued: Instant,
    reply: Arc<ReplySlot>,
}

struct QueueState {
    pending: Vec<PendingReq>,
    pending_rows: usize,
    /// Arrival time of the oldest slab in the current batch window.
    first_enqueue: Option<Instant>,
    /// Arrival time of the newest slab (drives the adaptive quiet cut).
    last_enqueue: Option<Instant>,
    /// Intra-window inter-arrival gap estimator (adaptive policy only).
    adaptive: AdaptiveWait,
    /// Live client handles; the server exits when this AND `holds` reach
    /// zero.
    active_clients: usize,
    /// Registration leases ([`InferenceServer::hold`]): a supervisor
    /// respawning a panicked worker holds one so the momentary zero-client
    /// window between the old client's drop and the respawned worker's
    /// re-registration can't be mistaken for fleet shutdown. Holds never
    /// submit, so they don't count toward the full-batch condition.
    holds: usize,
    /// Set once the serve loop has exited: submits fail fast.
    /// [`InferenceServer::revive`] clears it when a supervisor respawns
    /// the serve thread.
    server_down: bool,
}

/// Scripted shard faults (chaos harness): armed cells checked against the
/// lifetime dispatch counter, plus the fleet-wide injected-fault counter.
struct ShardFaults {
    cells: Vec<Arc<FaultCell>>,
    injected: Arc<AtomicU64>,
}

struct ServerShared {
    cfg: InferenceServerCfg,
    q: Mutex<QueueState>,
    submitted: Condvar,
    metrics: Mutex<InferenceReport>,
    /// Hot-path buffer-growth events (client + server side). Flat after
    /// warmup == the steady-state tick allocates nothing.
    hot_allocs: AtomicU64,
    /// Pool-wide epoch gate (None = gateless: this shard observes the
    /// store independently, the `--infer-epoch shard` escape hatch and
    /// the standalone-server default).
    gate: Option<Arc<EpochGate>>,
    /// Scripted fault cells (`--fault-inject`; unset = one lock-free
    /// `get()` per dispatch and nothing else).
    faults: OnceLock<ShardFaults>,
    /// Lifetime dispatch counter — survives serve-thread respawns, so a
    /// fault armed at dispatch D fires exactly once even when an earlier
    /// fault already restarted the shard.
    dispatches: AtomicU64,
}

/// One shard of the shared-inference pool: owns the request queue and (on
/// its serve thread) the fleet-slice actor. Standalone use (tests,
/// benches) is a one-shard pool.
pub struct InferenceServer {
    shared: Arc<ServerShared>,
}

/// Worker-side handle: submit one slab, block until the shard's next
/// dispatch answers it. Dropping the handle deregisters the worker so the
/// server stops waiting for it (and exits once all clients are gone).
pub struct ActorClient {
    shared: Arc<ServerShared>,
    slot: Arc<ReplySlot>,
}

impl InferenceServer {
    /// A gateless shard: observes the policy store independently per
    /// dispatch (standalone servers, tests, `--infer-epoch shard`).
    pub fn new(cfg: InferenceServerCfg) -> InferenceServer {
        Self::with_gate(cfg, None)
    }

    /// A shard wired to a pool-wide [`EpochGate`]: policy observations go
    /// through the gate, which flips all shards of the pool to a new
    /// snapshot on the same dispatch boundary ([`InferencePool::new`]
    /// under `EpochMode::Pool`).
    pub fn with_gate(cfg: InferenceServerCfg, gate: Option<Arc<EpochGate>>) -> InferenceServer {
        let (fleet_rows, hist_rows) = (cfg.fleet_rows, cfg.hist_rows);
        InferenceServer {
            shared: Arc::new(ServerShared {
                cfg,
                q: Mutex::new(QueueState {
                    pending: Vec::new(),
                    pending_rows: 0,
                    first_enqueue: None,
                    last_enqueue: None,
                    adaptive: AdaptiveWait::new(),
                    active_clients: 0,
                    holds: 0,
                    server_down: false,
                }),
                submitted: Condvar::new(),
                metrics: Mutex::new(InferenceReport::with_bounds(fleet_rows, hist_rows)),
                hot_allocs: AtomicU64::new(0),
                gate,
                faults: OnceLock::new(),
                dispatches: AtomicU64::new(0),
            }),
        }
    }

    /// This shard's row capacity.
    pub fn fleet_rows(&self) -> usize {
        self.shared.cfg.fleet_rows
    }

    /// Register a worker and hand out its submission handle. Create every
    /// client BEFORE spawning the serve thread, or the server may observe
    /// zero active clients and exit immediately.
    pub fn client(&self) -> ActorClient {
        {
            let mut q = plock(&self.shared.q);
            q.active_clients += 1;
            // pre-size the pending queue to the client count so steady-
            // state submits never grow it
            let want = q.active_clients;
            if q.pending.capacity() < want {
                let len = q.pending.len();
                q.pending.reserve_exact(want - len);
            }
        }
        ActorClient {
            shared: self.shared.clone(),
            slot: Arc::new(ReplySlot {
                cell: Mutex::new(None),
                ready: Condvar::new(),
                spare: Mutex::new(Vec::with_capacity(2)),
            }),
        }
    }

    /// Take a registration lease: while any hold is alive the serve loop
    /// treats a zero-client queue as "workers are between registrations"
    /// (idle-waits) instead of "fleet shut down" (exits). Supervisors take
    /// one hold per supervised worker so a respawn's momentary
    /// drop-then-re-register window can't shut the shard down. Holds do
    /// not affect batching — only the exit condition.
    pub fn hold(&self) -> ClientHold {
        plock(&self.shared.q).holds += 1;
        ClientHold {
            shared: self.shared.clone(),
        }
    }

    /// Arm scripted fault cells against this shard's lifetime dispatch
    /// counter (`--fault-inject`). Set-once: later calls are ignored.
    /// `injected` is the fleet-wide injected-fault counter bumped when a
    /// cell fires.
    pub fn arm_faults(&self, cells: Vec<Arc<FaultCell>>, injected: Arc<AtomicU64>) {
        let _ = self.shared.faults.set(ShardFaults { cells, injected });
    }

    /// Clear the down marker and rejoin the pool epoch gate — called by
    /// the supervisor (and [`InferenceServer::serve_algo`] on entry)
    /// before a respawned serve thread starts dispatching again, so
    /// client submits stop failing fast and the revived shard
    /// participates in flips.
    pub fn revive(&self) {
        plock(&self.shared.q).server_down = false;
        if let Some(gate) = &self.shared.gate {
            gate.join(self.shared.cfg.shard_id);
        }
    }

    /// Snapshot of the dispatch statistics (valid any time; final after
    /// the serve thread exits).
    pub fn report(&self) -> InferenceReport {
        let mut r = plock(&self.shared.metrics).clone();
        r.hot_allocs = self.shared.hot_allocs.load(Ordering::Relaxed);
        r
    }

    /// Serve `algo`'s act requests on the current thread until every
    /// client handle is dropped. Builds the fleet-slice backend here
    /// through [`Algorithm::make_server_actor`] (backends are
    /// thread-local on the XLA path) — the serve loop itself is fully
    /// algorithm-agnostic, so a new algorithm plugs into the pool with
    /// zero edits to this module.
    pub fn serve_algo(
        &self,
        algo: &dyn Algorithm,
        factory: &dyn BackendFactory,
        store: &PolicyStore,
    ) -> anyhow::Result<()> {
        // guard FIRST: a panic anywhere past this point — including one
        // inside backend construction — must fail blocked clients
        // instead of stranding them on their completion slots
        let _guard = DownGuard(self);
        // respawn path: clear a previous unwind's down marker and rejoin
        // the flip barrier (no-ops on first entry)
        self.revive();
        let actor = match algo.make_server_actor(factory, self.shared.cfg.fleet_rows) {
            Ok(a) => a,
            Err(e) => {
                self.fail_all(&format!(
                    "shared {} actor construction failed: {e:#}",
                    algo.name()
                ));
                return Err(e);
            }
        };
        self.serve(actor, store)
    }

    /// Serve PPO `act` requests: thin wrapper over
    /// [`InferenceServer::serve_algo`] with the PPO algorithm.
    pub fn serve_ppo(
        &self,
        factory: &dyn BackendFactory,
        store: &PolicyStore,
    ) -> anyhow::Result<()> {
        self.serve_algo(&crate::algo::ppo::Ppo::default(), factory, store)
    }

    /// DDPG counterpart of [`InferenceServer::serve_ppo`].
    pub fn serve_ddpg(
        &self,
        factory: &dyn BackendFactory,
        store: &PolicyStore,
    ) -> anyhow::Result<()> {
        self.serve_algo(&crate::algo::ddpg::Ddpg::default(), factory, store)
    }

    /// Mark the server down, fail every pending request (and all future
    /// submits), and leave the pool epoch gate. Called on any serve exit
    /// path, including unwinds — so it must tolerate a poisoned queue
    /// lock (a panic mid-dispatch must not escalate to a double panic, it
    /// must release the fleet). Idempotent.
    fn fail_all(&self, msg: &str) {
        {
            let mut q = plock(&self.shared.q);
            q.server_down = true;
            q.pending_rows = 0;
            q.first_enqueue = None;
            q.last_enqueue = None;
            for req in q.pending.drain(..) {
                reply(&req.reply, Err(msg.to_string()));
            }
        }
        // a dead shard must not wedge the surviving shards' flip barrier
        if let Some(gate) = &self.shared.gate {
            gate.leave(self.shared.cfg.shard_id);
        }
    }

    fn serve(&self, mut backend: Box<dyn ServerActor>, store: &PolicyStore) -> anyhow::Result<()> {
        let sh = &*self.shared;
        let o = sh.cfg.obs_dim;
        let a = sh.cfg.act_dim;
        // fixed > 0: shape-specialized backend (XLA artifact); partial
        // dispatches are padded up to `fixed` with zero rows whose outputs
        // are dropped. fixed == 0: flexible backend, every forward carries
        // exactly the real rows (the native path — padding-free).
        let fixed = backend.fixed_batch();
        if fixed > 0 && fixed < sh.cfg.fleet_rows {
            let msg = format!(
                "infer shard {}: backend batch {fixed} cannot hold the shard's {} rows",
                sh.cfg.shard_id, sh.cfg.fleet_rows
            );
            self.fail_all(&msg);
            anyhow::bail!(msg);
        }
        let cap = if fixed > 0 {
            fixed.max(sh.cfg.fleet_rows)
        } else {
            sh.cfg.fleet_rows
        };
        let mut obs_buf = vec![0.0f32; cap * o];
        let mut noise_buf = vec![0.0f32; cap * a];
        // recycled batch vec: swapped with the pending queue per dispatch,
        // so steady state moves requests without allocating. Guarded: a
        // panic between gather and scatter (injected fault, backend bug)
        // fails the in-flight requests on unwind instead of dropping them
        // silently — a client whose request dies mid-dispatch gets an Err
        // promptly even if a supervisor revives the shard before the
        // client's next liveness probe.
        let mut batch = BatchGuard { reqs: Vec::new() };
        let shard_label = format!("infer shard {}", sh.cfg.shard_id);
        // Idle-wait period. The gate has no push channel from the store
        // (proposals are discovered by shards polling), so a gated shard
        // polls its idle branch fast enough that a momentarily idle shard
        // delays a pool-wide flip by at most ~5ms.
        let idle_wait = if sh.gate.is_some() {
            Duration::from_millis(5)
        } else {
            Duration::from_millis(50)
        };

        loop {
            debug_assert!(batch.is_empty(), "batch drained before re-gather");
            // ---- gather one batch under the straggler-cut policy -------
            // `cut_us` records the budget that forced a timeout dispatch.
            let (was_full, cut_us) = {
                let mut q = plock(&sh.q);
                loop {
                    if q.pending.is_empty() {
                        if q.active_clients == 0 && q.holds == 0 {
                            drop(q);
                            self.fail_all("inference server shut down");
                            return Ok(());
                        }
                        // an idle shard still participates in the epoch
                        // protocol: it acks pending flips from here so a
                        // shard with parked workers (sync-mode barrier)
                        // can never wedge the pool-wide flip
                        if let Some(gate) = &sh.gate {
                            gate.poll(sh.cfg.shard_id, store);
                        }
                        q = cv_wait(&sh.submitted, q, idle_wait);
                        continue;
                    }
                    let full = q.pending.len() >= q.active_clients
                        || q.pending_rows >= sh.cfg.fleet_rows;
                    let first = q.first_enqueue.expect("pending implies first_enqueue");
                    let (deadline, budget_us) = match sh.cfg.wait {
                        WaitPolicy::Fixed(d) => (first + d, d.as_secs_f64() * 1e6),
                        WaitPolicy::Adaptive => {
                            // quiet cut from the newest arrival, hard-
                            // capped from the oldest so an unprimed or
                            // noisy estimator can't stall the shard
                            let cut = q.adaptive.cut_us();
                            let last = q.last_enqueue.unwrap_or(first);
                            let dl = std::cmp::min(
                                last + Duration::from_micros(cut as u64),
                                first + Duration::from_micros(ADAPTIVE_MAX_CUT_US as u64),
                            );
                            (dl, cut)
                        }
                    };
                    let now = Instant::now();
                    if full || now >= deadline {
                        q.pending_rows = 0;
                        q.first_enqueue = None;
                        q.last_enqueue = None;
                        std::mem::swap(&mut q.pending, &mut batch.reqs);
                        break (full, budget_us);
                    }
                    q = cv_wait(&sh.submitted, q, deadline - now);
                }
            };

            // ---- scripted fault point (chaos harness) ------------------
            // One lifetime dispatch number per gathered batch; an armed
            // cell panics here, exercising the same unwind the supervisor
            // must heal for a genuine serve-loop defect. Off = one
            // lock-free `get()` and a branch.
            let dispatch_no = sh.dispatches.fetch_add(1, Ordering::SeqCst) + 1;
            if let Some(f) = sh.faults.get() {
                crate::util::fault::trip(&f.cells, dispatch_no, &f.injected, &shard_label);
            }

            // ---- one policy observation per dispatch -------------------
            // Pool epochs: the gate hands every shard the same snapshot
            // and parks this shard at the flip barrier while a publish is
            // pending, so no shard dispatches under the new version until
            // every shard has drained its in-flight window. Gateless
            // shards poll the store independently (epoch reported as 0).
            let (snapshot, epoch, flip_stall_us) = match &sh.gate {
                Some(gate) => {
                    let lease = gate.acquire(sh.cfg.shard_id, store);
                    (lease.snapshot, lease.epoch, lease.flip_stall_us)
                }
                None => {
                    let snap = loop {
                        match store.latest() {
                            Some(s) => break s,
                            // clients gate on the first publish, so this
                            // only spins in pathological test setups
                            None => std::thread::sleep(Duration::from_millis(1)),
                        }
                    };
                    (snap, 0, None)
                }
            };

            // ---- pack + normalize the mega-batch -----------------------
            let rows: usize = batch.iter().map(|r| r.rows).sum();
            let dispatched_at = Instant::now();
            let busy_t0 = crate::util::timer::thread_cpu_secs();
            debug_assert!(rows <= cap, "batch of {rows} rows exceeds capacity {cap}");
            let mut cursor = 0usize;
            for req in batch.iter() {
                let n = req.rows * o;
                obs_buf[cursor * o..cursor * o + n].copy_from_slice(&req.bufs.obs[..n]);
                for r in 0..req.rows {
                    let row = &mut obs_buf[(cursor + r) * o..(cursor + r + 1) * o];
                    snapshot.norm.apply(row);
                }
                if !req.bufs.noise.is_empty() {
                    noise_buf[cursor * a..cursor * a + req.rows * a]
                        .copy_from_slice(&req.bufs.noise[..req.rows * a]);
                }
                cursor += req.rows;
            }
            let fwd_rows = if fixed > 0 { fixed } else { rows };
            for z in &mut obs_buf[rows * o..fwd_rows * o] {
                *z = 0.0; // padding rows (fixed-batch backends only)
            }
            for z in &mut noise_buf[rows * a..fwd_rows * a] {
                *z = 0.0;
            }

            // ---- the one forward ---------------------------------------
            let result = backend.forward(
                &snapshot,
                &obs_buf[..fwd_rows * o],
                &noise_buf[..fwd_rows * a],
                rows,
                a,
            );
            let dispatch_busy = crate::util::timer::thread_cpu_secs() - busy_t0;

            // ---- metrics -----------------------------------------------
            {
                let mut m = plock(&sh.metrics);
                m.forwards += 1;
                m.rows += rows as u64;
                if was_full {
                    m.full_dispatches += 1;
                } else {
                    m.timeout_dispatches += 1;
                    m.cut_us.record(cut_us);
                }
                // versions the served snapshot lags the newest publish
                // (gate mode: how long flips park behind the barrier;
                // shard mode: raw observation staleness)
                m.epoch_lag
                    .record(store.version().saturating_sub(snapshot.version) as f64);
                if let Some(us) = flip_stall_us {
                    m.flip_stall_us.record(us);
                }
                m.dispatch_rows.record(rows as f64);
                m.fill_ratio.record(rows as f64 / sh.cfg.fleet_rows as f64);
                for req in batch.iter() {
                    m.queue_wait_us
                        .record((dispatched_at - req.enqueued).as_secs_f64() * 1e6);
                }
            }

            // ---- scatter responses -------------------------------------
            match result {
                Ok(res) => {
                    let mut cursor = 0usize;
                    for mut req in batch.drain(..) {
                        let (r0, r1) = (cursor, cursor + req.rows);
                        let b = &mut req.bufs;
                        ensure_len(&mut b.action, req.rows * a, &sh.hot_allocs);
                        b.action.copy_from_slice(&res.action[r0 * a..r1 * a]);
                        ensure_len(&mut b.mean, req.rows * a, &sh.hot_allocs);
                        // DDPG backends leave mean empty: action IS the mean
                        let mean_src = if res.mean.is_empty() {
                            &res.action
                        } else {
                            &res.mean
                        };
                        b.mean.copy_from_slice(&mean_src[r0 * a..r1 * a]);
                        ensure_len(&mut b.logp, req.rows, &sh.hot_allocs);
                        ensure_len(&mut b.value, req.rows, &sh.hot_allocs);
                        if res.logp.is_empty() {
                            b.logp.fill(0.0); // deterministic DDPG actor
                            b.value.fill(0.0);
                        } else {
                            b.logp.copy_from_slice(&res.logp[r0..r1]);
                            b.value.copy_from_slice(&res.value[r0..r1]);
                        }
                        // return the obs rows normalized under the
                        // dispatch snapshot (what the policy actually saw)
                        b.obs[..req.rows * o].copy_from_slice(&obs_buf[r0 * o..r1 * o]);
                        let slot = req.reply;
                        reply(
                            &slot,
                            Ok(Reply {
                                bufs: req.bufs,
                                rows: req.rows,
                                snapshot: snapshot.clone(),
                                epoch,
                                server_busy_secs: dispatch_busy * req.rows as f64
                                    / rows as f64,
                            }),
                        );
                        cursor = r1;
                    }
                }
                Err(e) => {
                    // reply the error to every slab in the dispatch and
                    // keep serving: workers terminate themselves exactly
                    // like a local-backend act failure
                    let msg = format!(
                        "infer shard {}: shared inference forward failed: {e:#}",
                        sh.cfg.shard_id
                    );
                    crate::log_error!("{msg}");
                    for req in batch.drain(..) {
                        reply(&req.reply, Err(msg.clone()));
                    }
                }
            }
        }
    }
}

/// The serve loop's in-flight batch. Between gather and scatter the
/// requests live here, OUTSIDE the queue — so `fail_all` (which drains
/// the queue) cannot see them. If the serve thread unwinds in that window
/// (injected fault, backend panic), this guard's `Drop` fails each one,
/// closing the race where a fast supervisor revive clears `server_down`
/// before the blocked clients' next liveness probe and they wait forever
/// on slots nobody will ever fill.
struct BatchGuard {
    reqs: Vec<PendingReq>,
}

impl std::ops::Deref for BatchGuard {
    type Target = Vec<PendingReq>;
    fn deref(&self) -> &Vec<PendingReq> {
        &self.reqs
    }
}

impl std::ops::DerefMut for BatchGuard {
    fn deref_mut(&mut self) -> &mut Vec<PendingReq> {
        &mut self.reqs
    }
}

impl Drop for BatchGuard {
    fn drop(&mut self) {
        // empty on every normal exit path (scatter drains it); only an
        // unwind mid-dispatch reaches here with requests in flight
        for req in self.reqs.drain(..) {
            reply(
                &req.reply,
                Err("inference dispatch aborted mid-flight".to_string()),
            );
        }
    }
}

/// Sentinel marking the shard down on ANY serve exit — ordinary returns,
/// `?` errors, and panics (backend bugs, bad artifact shapes) alike — so
/// blocked clients always unwind with an error instead of spinning on
/// their completion slots forever. Idempotent with the explicit fail_all
/// calls on clean exit paths.
struct DownGuard<'a>(&'a InferenceServer);

impl Drop for DownGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            crate::log_error!(
                "infer shard {}: serve thread panicked; failing its blocked workers",
                self.0.shared.cfg.shard_id
            );
        }
        self.0.fail_all("inference server terminated unexpectedly");
    }
}

fn reply(slot: &ReplySlot, r: Result<Reply, String>) {
    *plock(&slot.cell) = Some(r);
    slot.ready.notify_one();
}

/// A leased request slab: the client's recycled [`SlabBuffers`] handed
/// out BEFORE submission so the worker fills the obs (and noise) rows in
/// place — the batched env engine's `step_all` writes next observations
/// straight into the request slab, eliminating the staging copy
/// [`ActorClient::act`] performs. Obtain one with [`ActorClient::lease`],
/// fill [`SlabLease::obs_mut`] / [`SlabLease::noise_mut`], submit with
/// [`ActorClient::act_leased`]. Dropping an unsubmitted lease returns the
/// buffers to the client's spare pool.
pub struct SlabLease {
    bufs: Option<SlabBuffers>,
    rows: usize,
    obs_dim: usize,
    act_dim: usize,
    noise_rows: bool,
    home: Arc<ReplySlot>,
}

impl SlabLease {
    /// Leased rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// The raw obs slab to fill ([rows * obs_dim], row-major).
    pub fn obs_mut(&mut self) -> &mut [f32] {
        let n = self.rows * self.obs_dim;
        &mut self.bufs.as_mut().expect("buffers present until drop").obs[..n]
    }

    /// The N(0,1) noise slab to fill ([rows * act_dim]); empty when the
    /// lease was taken without noise (deterministic actors).
    pub fn noise_mut(&mut self) -> &mut [f32] {
        let n = if self.noise_rows {
            self.rows * self.act_dim
        } else {
            0
        };
        &mut self.bufs.as_mut().expect("buffers present until drop").noise[..n]
    }
}

impl Drop for SlabLease {
    fn drop(&mut self) {
        // abandoned lease (worker error path): recycle, don't leak
        if let Some(b) = self.bufs.take() {
            plock(&self.home.spare).push(b);
        }
    }
}

impl ActorClient {
    /// Submit this worker's slab (raw obs, per-row noise) and block until
    /// the shard's dispatch answers it. `noise` must hold `rows *
    /// act_dim` N(0,1) draws for PPO, or be empty for DDPG. Drop the
    /// returned [`ActResponse`] before the next call so its buffers
    /// recycle (holding it across ticks forces a warm-up reallocation,
    /// nothing worse). Workers that already produce their obs in a slab
    /// of their own can skip this method's staging copy via
    /// [`ActorClient::lease`] + [`ActorClient::act_leased`].
    pub fn act(&mut self, raw_obs: &[f32], noise: &[f32]) -> anyhow::Result<ActResponse> {
        let o = self.shared.cfg.obs_dim;
        let a = self.shared.cfg.act_dim;
        anyhow::ensure!(
            !raw_obs.is_empty() && raw_obs.len() % o == 0,
            "client slab must be a whole number of obs rows"
        );
        let rows = raw_obs.len() / o;
        anyhow::ensure!(
            noise.is_empty() || noise.len() == rows * a,
            "noise must be empty (ddpg) or rows * act_dim"
        );
        let mut lease = self.lease(rows, !noise.is_empty())?;
        lease.obs_mut().copy_from_slice(raw_obs);
        lease.noise_mut().copy_from_slice(noise);
        self.act_leased(lease)
    }

    /// Check out this tick's request buffers for in-place filling (the
    /// zero-copy submission path; see [`SlabLease`]). `want_noise` sizes
    /// the noise slab to `rows * act_dim` (stochastic actors) or zero
    /// (deterministic).
    pub fn lease(&mut self, rows: usize, want_noise: bool) -> anyhow::Result<SlabLease> {
        let sh = &*self.shared;
        anyhow::ensure!(rows > 0, "lease must cover at least one row");
        anyhow::ensure!(
            rows <= sh.cfg.fleet_rows,
            "slab of {rows} rows exceeds shard capacity {}",
            sh.cfg.fleet_rows
        );
        // reclaim the recycled buffers (first call allocates: warmup)
        let mut bufs = match plock(&self.slot.spare).pop() {
            Some(b) => b,
            None => {
                sh.hot_allocs.fetch_add(1, Ordering::Relaxed);
                SlabBuffers::default()
            }
        };
        ensure_len(&mut bufs.obs, rows * sh.cfg.obs_dim, &sh.hot_allocs);
        let noise_len = if want_noise { rows * sh.cfg.act_dim } else { 0 };
        ensure_len(&mut bufs.noise, noise_len, &sh.hot_allocs);
        Ok(SlabLease {
            bufs: Some(bufs),
            rows,
            obs_dim: sh.cfg.obs_dim,
            act_dim: sh.cfg.act_dim,
            noise_rows: want_noise,
            home: self.slot.clone(),
        })
    }

    /// Submit a filled [`SlabLease`] and block until the shard's dispatch
    /// answers it — [`ActorClient::act`] without the staging copy.
    pub fn act_leased(&mut self, mut lease: SlabLease) -> anyhow::Result<ActResponse> {
        let sh = &*self.shared;
        let rows = lease.rows;
        let bufs = lease.bufs.take().expect("lease buffers present");
        {
            let mut q = plock(&sh.q);
            anyhow::ensure!(!q.server_down, "inference server is down");
            let now = Instant::now();
            if matches!(sh.cfg.wait, WaitPolicy::Adaptive) {
                // intra-window gap only: across-window gaps include the
                // forward + env-step time, not queueing behavior
                if let (Some(_), Some(last)) = (q.first_enqueue, q.last_enqueue) {
                    q.adaptive.observe((now - last).as_secs_f64() * 1e6);
                }
            }
            if q.pending.len() == q.pending.capacity() {
                sh.hot_allocs.fetch_add(1, Ordering::Relaxed);
            }
            q.pending.push(PendingReq {
                rows,
                bufs,
                enqueued: now,
                reply: self.slot.clone(),
            });
            q.pending_rows += rows;
            q.first_enqueue.get_or_insert(now);
            q.last_enqueue = Some(now);
        }
        sh.submitted.notify_all();

        // await the completion slot; periodically probe server liveness
        // (never hold the slot lock while probing — server replies while
        // holding the queue lock on its exit path)
        let mut cell = plock(&self.slot.cell);
        loop {
            if let Some(r) = cell.take() {
                drop(cell);
                return self.unpack(r);
            }
            cell = cv_wait(&self.slot.ready, cell, Duration::from_millis(50));
            if cell.is_some() {
                continue;
            }
            drop(cell);
            if plock(&self.shared.q).server_down {
                let mut c = plock(&self.slot.cell);
                // the terminal reply may have landed in the gap
                if let Some(r) = c.take() {
                    drop(c);
                    return self.unpack(r);
                }
                anyhow::bail!("inference server terminated");
            }
            cell = plock(&self.slot.cell);
        }
    }

    /// Discard a reply parked in the completion slot by a PREVIOUS
    /// incarnation of this worker, recycling its buffers. The daemon
    /// re-hands a stashed client to a respawned remote child; an answer
    /// the dead child never collected must not be served as the new
    /// child's first response (it would be one tick stale).
    pub fn reset_stale(&mut self) {
        if let Some(Ok(reply)) = plock(&self.slot.cell).take() {
            plock(&self.slot.spare).push(reply.bufs);
        }
    }

    fn unpack(&self, r: Result<Reply, String>) -> anyhow::Result<ActResponse> {
        let reply = r.map_err(|e| anyhow::anyhow!(e))?;
        Ok(ActResponse {
            rows: reply.rows,
            obs_dim: self.shared.cfg.obs_dim,
            act_dim: self.shared.cfg.act_dim,
            bufs: Some(reply.bufs),
            home: self.slot.clone(),
            snapshot: reply.snapshot,
            epoch: reply.epoch,
            server_busy_secs: reply.server_busy_secs,
        })
    }
}

impl Drop for ActorClient {
    fn drop(&mut self) {
        // poison-tolerant: a worker unwinding past its client must still
        // deregister, or the server would wait on a dead peer forever
        let mut q = plock(&self.shared.q);
        q.active_clients = q.active_clients.saturating_sub(1);
        drop(q);
        // wake the server so it re-evaluates the full-batch condition
        // (remaining workers shouldn't wait out the cut for a dead peer)
        self.shared.submitted.notify_all();
    }
}

/// Registration lease handed out by [`InferenceServer::hold`]. While any
/// lease is alive the shard's serve loop keeps running through
/// zero-client windows instead of treating them as fleet shutdown. Drop
/// all holds (the supervisor does, once its workers are permanently done)
/// to let the shard exit cleanly.
pub struct ClientHold {
    shared: Arc<ServerShared>,
}

impl Drop for ClientHold {
    fn drop(&mut self) {
        let mut q = plock(&self.shared.q);
        q.holds = q.holds.saturating_sub(1);
        drop(q);
        // wake a serve loop idling on the lease so it can re-check the
        // exit condition
        self.shared.submitted.notify_all();
    }
}

// -------------------------------------------------- remote response depot

/// Buffer home for [`ActResponse`]s assembled OUTSIDE an inference shard.
/// The remote-client path (`runtime::daemon`) decodes a wire reply in a
/// sampler process and hands the hot loop the same [`ActResponse`] type
/// the in-process path produces — drop-recycling included, so the remote
/// tick allocates nothing at steady state either. The depot owns the
/// spare slot that dropped responses return their buffers to.
pub struct ResponseDepot {
    obs_dim: usize,
    act_dim: usize,
    home: Arc<ReplySlot>,
}

impl ResponseDepot {
    pub fn new(obs_dim: usize, act_dim: usize) -> ResponseDepot {
        ResponseDepot {
            obs_dim,
            act_dim,
            home: Arc::new(ReplySlot {
                cell: Mutex::new(None),
                ready: Condvar::new(),
                spare: Mutex::new(Vec::with_capacity(2)),
            }),
        }
    }

    /// Check out a recycled buffer set (a default-empty [`SlabBuffers`]
    /// on warmup — the caller resizes while decoding the reply).
    pub fn buffers(&self) -> SlabBuffers {
        plock(&self.home.spare).pop().unwrap_or_default()
    }

    /// Wrap decoded reply buffers into an [`ActResponse`]; dropping it
    /// returns the buffers to this depot. Every reply slab in `bufs`
    /// must hold at least `rows` rows (the accessors slice to `rows`).
    pub fn response(
        &self,
        bufs: SlabBuffers,
        rows: usize,
        snapshot: Arc<PolicySnapshot>,
        epoch: u64,
        server_busy_secs: f64,
    ) -> ActResponse {
        ActResponse {
            bufs: Some(bufs),
            rows,
            obs_dim: self.obs_dim,
            act_dim: self.act_dim,
            home: self.home.clone(),
            snapshot,
            epoch,
            server_busy_secs,
        }
    }
}

// ------------------------------------------------------------------ pool

/// Configuration of the sharded pool (derived from `TrainConfig` by the
/// orchestrator; `shards` is already resolved — see
/// `config::InferShards::resolve`).
#[derive(Debug, Clone)]
pub struct InferencePoolCfg {
    /// N sampler workers served by the pool.
    pub workers: usize,
    /// M rows each worker submits per tick (`envs_per_sampler`).
    pub rows_per_worker: usize,
    /// Resolved shard count S (clamped to [1, workers]).
    pub shards: usize,
    /// Straggler-cut policy applied by every shard.
    pub wait: WaitPolicy,
    /// How the pool adopts newly published policy versions: `Pool` wires
    /// every shard to one [`EpochGate`] (all S flip on the same dispatch
    /// boundary); `Shard` lets each shard observe the store independently
    /// (the pre-epoch behavior, `--infer-epoch shard`).
    pub epoch: EpochMode,
    pub obs_dim: usize,
    pub act_dim: usize,
}

/// S inference shards with a deterministic static worker assignment:
/// worker `w` is served by shard `w % S`, so each shard owns an actor
/// sized to exactly its workers' rows and per-env streams are independent
/// of S (see the module docs for the invariant).
pub struct InferencePool {
    shards: Vec<Arc<InferenceServer>>,
    /// The pool-wide epoch barrier (None under `EpochMode::Shard`).
    gate: Option<Arc<EpochGate>>,
}

impl InferencePool {
    /// A pool whose epoch gate (if any) acks flips at every dispatch
    /// boundary — the default, lowest-latency adoption.
    pub fn new(cfg: InferencePoolCfg) -> InferencePool {
        Self::with_flip_schedule(cfg, 0)
    }

    /// A pool whose epoch gate acks pending flips only on dispatch
    /// numbers divisible by `flip_schedule` (`--flip-schedule`; 0 = every
    /// boundary). Pinning flips to a coarse deterministic tick schedule
    /// makes async-mode version adoption reproducible run-to-run. No-op
    /// under `EpochMode::Shard` (no gate to schedule).
    pub fn with_flip_schedule(cfg: InferencePoolCfg, flip_schedule: u64) -> InferencePool {
        let workers = cfg.workers.max(1);
        let s = cfg.shards.clamp(1, workers);
        let gate = match cfg.epoch {
            EpochMode::Pool => Some(Arc::new(EpochGate::with_schedule(s, flip_schedule))),
            EpochMode::Shard => None,
        };
        // shard i serves workers {w : w % s == i}: n/s workers each, the
        // first n%s shards carry one extra
        let max_shard_workers = workers.div_euclid(s) + usize::from(workers % s > 0);
        let hist_rows = max_shard_workers * cfg.rows_per_worker;
        let shards = (0..s)
            .map(|i| {
                let shard_workers = workers / s + usize::from(i < workers % s);
                Arc::new(InferenceServer::with_gate(
                    InferenceServerCfg {
                        wait: cfg.wait,
                        fleet_rows: shard_workers * cfg.rows_per_worker,
                        obs_dim: cfg.obs_dim,
                        act_dim: cfg.act_dim,
                        shard_id: i,
                        hist_rows,
                    },
                    gate.clone(),
                ))
            })
            .collect();
        InferencePool { shards, gate }
    }

    /// The pool-wide epoch gate (None when running `--infer-epoch shard`).
    pub fn epoch_gate(&self) -> Option<&Arc<EpochGate>> {
        self.gate.as_ref()
    }

    /// Resolved shard count S.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shards, for spawning one serve thread each (the orchestrator
    /// calls [`InferenceServer::serve_algo`] on every element).
    pub fn shards(&self) -> &[Arc<InferenceServer>] {
        &self.shards
    }

    /// The static assignment: worker `worker_id`'s shard.
    pub fn shard_for(&self, worker_id: usize) -> &Arc<InferenceServer> {
        &self.shards[worker_id % self.shards.len()]
    }

    /// Register worker `worker_id` with its shard and hand out the
    /// submission handle. Call for every worker BEFORE spawning the serve
    /// threads.
    pub fn client(&self, worker_id: usize) -> ActorClient {
        self.shard_for(worker_id).client()
    }

    /// Pool-wide dispatch statistics: every shard's report merged
    /// (`fleet_rows` sums to N*M, `shards` counts S).
    pub fn report(&self) -> InferenceReport {
        let mut it = self.shards.iter().map(|s| s.report());
        let mut total = it.next().expect("pool has at least one shard");
        for r in it {
            total.merge(&r);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::normalizer::NormSnapshot;
    use crate::config::{DdpgCfg, PpoCfg};
    use crate::runtime::native_backend::NativeFactory;
    use std::thread;

    fn factory(obs: usize, act: usize) -> NativeFactory {
        NativeFactory::new(obs, act, &[8, 8], PpoCfg::default(), DdpgCfg::default())
    }

    fn server(fleet_rows: usize, max_wait_ms: u64) -> InferenceServer {
        InferenceServer::new(InferenceServerCfg::single(
            WaitPolicy::Fixed(Duration::from_millis(max_wait_ms)),
            fleet_rows,
            3,
            1,
        ))
    }

    fn published_store(f: &NativeFactory) -> Arc<PolicyStore> {
        let store = Arc::new(PolicyStore::new());
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));
        store
    }

    /// The acceptance-criterion property: with all N workers in phase,
    /// the server issues exactly ONE forward per sim tick fleet-wide.
    #[test]
    fn in_phase_fleet_gets_one_forward_per_tick() {
        let n = 8;
        let ticks = 25;
        let f = factory(3, 1);
        let store = published_store(&f);
        let srv = Arc::new(server(n, 5_000)); // generous cut: never fires
        let clients: Vec<ActorClient> = (0..n).map(|_| srv.client()).collect();

        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });

        let mut worker_hs = Vec::new();
        for (w, mut client) in clients.into_iter().enumerate() {
            worker_hs.push(thread::spawn(move || {
                let obs = vec![0.1 * (w as f32 + 1.0); 3];
                let noise = vec![0.0f32; 1];
                for _ in 0..ticks {
                    let resp = client.act(&obs, &noise).unwrap();
                    assert_eq!(resp.action().len(), 1);
                    assert_eq!(resp.norm_obs(), &obs[..]); // identity norm
                    assert_eq!(resp.snapshot.version, 1);
                }
            }));
        }
        for h in worker_hs {
            h.join().unwrap();
        }
        // all clients dropped inside the worker threads -> server exits
        server_h.join().unwrap().unwrap();

        let rep = srv.report();
        assert_eq!(
            rep.forwards, ticks as u64,
            "expected exactly one forward per tick"
        );
        assert_eq!(rep.rows, (n * ticks) as u64);
        assert_eq!(rep.full_dispatches, ticks as u64);
        assert_eq!(rep.timeout_dispatches, 0);
        assert!((rep.mean_fill() - 1.0).abs() < 1e-9);
        assert_eq!(rep.shards, 1);
    }

    /// The straggler guard: with one worker parked, the other's slab must
    /// dispatch as a partial batch once the fixed cut elapses. (The
    /// per-shard variant lives in `pool_shard_timeout_cut_is_per_shard`.)
    #[test]
    fn timeout_cut_dispatches_partial_batch_past_parked_worker() {
        let f = factory(3, 1);
        let store = published_store(&f);
        let srv = Arc::new(server(2, 30));
        let mut active = srv.client();
        let parked = srv.client(); // registered, never submits

        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });

        let t0 = Instant::now();
        let resp = active.act(&[0.1, 0.2, 0.3], &[0.0]).unwrap();
        let waited = t0.elapsed();
        assert_eq!(resp.action().len(), 1);
        assert!(
            waited >= Duration::from_millis(25),
            "dispatched before the cut: {waited:?}"
        );
        assert!(
            waited < Duration::from_secs(2),
            "straggler stalled the fleet: {waited:?}"
        );

        drop(resp);
        drop(active);
        drop(parked);
        server_h.join().unwrap().unwrap();
        let rep = srv.report();
        assert_eq!(rep.forwards, 1);
        assert_eq!(rep.timeout_dispatches, 1);
        assert_eq!(rep.full_dispatches, 0);
        assert!((rep.mean_fill() - 0.5).abs() < 1e-9);
        assert!(rep.queue_wait_us.mean() >= 25_000.0);
        // the cut histogram records the budget that fired (30ms fixed)
        assert_eq!(rep.cut_us.count(), 1);
        assert!((rep.cut_us.mean() - 30_000.0).abs() < 1.0);
    }

    /// Adaptive mode with a parked peer: the quiet cut (hard-capped at
    /// [`ADAPTIVE_MAX_CUT_US`]) must release the active worker promptly.
    #[test]
    fn adaptive_cut_releases_partial_batch_past_parked_worker() {
        let f = factory(3, 1);
        let store = published_store(&f);
        let srv = Arc::new(InferenceServer::new(InferenceServerCfg::single(
            WaitPolicy::Adaptive,
            2,
            3,
            1,
        )));
        let mut active = srv.client();
        let parked = srv.client();

        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });

        for _ in 0..5 {
            let t0 = Instant::now();
            let resp = active.act(&[0.1, 0.2, 0.3], &[0.0]).unwrap();
            assert_eq!(resp.value().len(), 1);
            // quiet cut <= hard cap (10ms) + generous scheduling slack
            assert!(
                t0.elapsed() < Duration::from_millis(500),
                "adaptive cut stalled behind a parked worker: {:?}",
                t0.elapsed()
            );
        }
        drop(active);
        drop(parked);
        server_h.join().unwrap().unwrap();
        let rep = srv.report();
        assert_eq!(rep.timeout_dispatches, 5);
        assert!(rep.cut_us.mean() <= ADAPTIVE_MAX_CUT_US + 1.0);
    }

    /// Batched results must equal per-worker local forwards row for row
    /// (the server adds no numerical perturbation).
    #[test]
    fn shared_rows_match_local_forward_bitwise() {
        let f = factory(3, 2);
        let store = Arc::new(PolicyStore::new());
        store.publish(f.init_ppo_params(3), NormSnapshot::identity(3));
        let srv = Arc::new(InferenceServer::new(InferenceServerCfg::single(
            WaitPolicy::Fixed(Duration::from_millis(500)),
            4,
            3,
            2,
        )));
        let mut c0 = srv.client();
        let mut c1 = srv.client();
        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 2);
            srv2.serve_ppo(&f, &store2)
        });

        let obs0 = vec![0.3, -0.1, 0.7, 0.2, 0.0, -0.5];
        let noise0 = vec![0.4, -0.2, 0.1, 0.9];
        let obs1 = vec![-0.9, 0.5, 0.05, 0.6, -0.3, 0.8];
        let noise1 = vec![-0.7, 0.3, 0.0, -0.1];
        let (o0c, n0c) = (obs0.clone(), noise0.clone());
        let h0 = thread::spawn(move || {
            let r = c0.act(&o0c, &n0c).unwrap();
            (r.action().to_vec(), r.logp().to_vec(), r.value().to_vec())
        });
        let (o1c, n1c) = (obs1.clone(), noise1.clone());
        let h1 = thread::spawn(move || {
            let r = c1.act(&o1c, &n1c).unwrap();
            (r.action().to_vec(), r.logp().to_vec(), r.value().to_vec())
        });
        let r0 = h0.join().unwrap();
        let r1 = h1.join().unwrap();
        server_h.join().unwrap().unwrap();

        let flat = f.init_ppo_params(3);
        let mut local = f.make_actor_batched(2).unwrap();
        let want0 = local.act(&flat, &obs0, &noise0).unwrap();
        let want1 = local.act(&flat, &obs1, &noise1).unwrap();
        assert_eq!(r0.0, want0.action);
        assert_eq!(r0.1, want0.logp);
        assert_eq!(r0.2, want0.value);
        assert_eq!(r1.0, want1.action);
        assert_eq!(r1.1, want1.logp);
        assert_eq!(r1.2, want1.value);
    }

    #[test]
    fn server_exits_when_all_clients_drop_and_rejects_late_submits() {
        let f = factory(3, 1);
        let store = published_store(&f);
        let srv = Arc::new(server(1, 10));
        let mut client = srv.client();
        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });
        client.act(&[0.0, 0.0, 0.0], &[0.0]).unwrap();
        drop(client);
        server_h.join().unwrap().unwrap();
        // a client created after shutdown fails fast instead of hanging
        let mut late = srv.client();
        assert!(late.act(&[0.0, 0.0, 0.0], &[0.0]).is_err());
    }

    #[test]
    fn ddpg_requests_use_empty_noise_and_zero_logp() {
        let f = factory(3, 1);
        let store = Arc::new(PolicyStore::new());
        let (actor_params, _) = f.init_ddpg_params(0);
        store.publish(actor_params.clone(), NormSnapshot::identity(3));
        let srv = Arc::new(server(2, 20));
        let mut client = srv.client();
        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ddpg(&f, &store2)
        });
        let resp = client.act(&[0.2, -0.2, 0.4, 0.1, 0.3, -0.6], &[]).unwrap();
        assert_eq!(resp.action().len(), 2);
        assert_eq!(resp.logp(), &[0.0, 0.0]);
        assert_eq!(resp.value(), &[0.0, 0.0]);
        assert_eq!(resp.mean(), resp.action());
        let mut local = f.make_ddpg_actor_batched(2).unwrap();
        let want = local
            .act(&actor_params, &[0.2, -0.2, 0.4, 0.1, 0.3, -0.6])
            .unwrap();
        assert_eq!(resp.action(), &want[..]);
        drop(resp);
        drop(client);
        server_h.join().unwrap().unwrap();
    }

    #[test]
    fn client_validates_slab_shapes() {
        let srv = server(4, 10);
        let mut client = srv.client();
        // not a whole number of rows
        assert!(client.act(&[0.0, 0.0], &[]).is_err());
        // bad noise length
        assert!(client.act(&[0.0; 3], &[0.0, 0.0]).is_err());
        // slab larger than the shard
        assert!(client.act(&[0.0; 15], &[]).is_err());
    }

    /// Steady-state hot path must stop allocating after warmup: the
    /// buffer-growth counter goes flat once every reusable buffer has
    /// reached its working size.
    #[test]
    fn steady_state_hot_path_allocates_nothing() {
        let n = 4;
        let f = factory(3, 1);
        let store = published_store(&f);
        let srv = Arc::new(server(n, 5_000));
        let clients: Vec<ActorClient> = (0..n).map(|_| srv.client()).collect();
        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });

        let barrier = Arc::new(std::sync::Barrier::new(n + 1));
        let warm = Arc::new(std::sync::Barrier::new(n + 1));
        let mut hs = Vec::new();
        for (w, mut client) in clients.into_iter().enumerate() {
            let barrier = barrier.clone();
            let warm = warm.clone();
            hs.push(thread::spawn(move || {
                let obs = vec![0.2 * (w as f32 + 1.0); 3];
                let noise = vec![0.1f32; 1];
                for _ in 0..10 {
                    client.act(&obs, &noise).unwrap();
                }
                warm.wait(); // every client fully warmed up
                barrier.wait(); // main thread snapshotted the counter
                for _ in 0..50 {
                    client.act(&obs, &noise).unwrap();
                }
            }));
        }
        warm.wait();
        let after_warmup = srv.report().hot_allocs;
        barrier.wait();
        for h in hs {
            h.join().unwrap();
        }
        server_h.join().unwrap().unwrap();
        let rep = srv.report();
        assert!(after_warmup > 0, "warmup must have allocated something");
        assert_eq!(
            rep.hot_allocs, after_warmup,
            "steady-state ticks allocated ({} -> {})",
            after_warmup, rep.hot_allocs
        );
        assert_eq!(rep.rows, (n * 60) as u64);
    }

    // ------------------------------------------------- adaptive estimator

    #[test]
    fn adaptive_wait_converges_on_constant_gaps() {
        let mut w = AdaptiveWait::new();
        assert_eq!(w.cut_us(), ADAPTIVE_DEFAULT_CUT_US);
        for _ in 0..500 {
            w.observe(50.0);
        }
        // ewma -> 50, deviation -> 0, cut -> 2*50 = 100
        let cut = w.cut_us();
        assert!(
            (95.0..=120.0).contains(&cut),
            "cut {cut} did not converge near 2x the 50us gap"
        );

        // a phase change re-converges within a few hundred observations
        for _ in 0..500 {
            w.observe(400.0);
        }
        let cut = w.cut_us();
        assert!(
            (760.0..=960.0).contains(&cut),
            "cut {cut} did not track the new 400us regime"
        );
    }

    #[test]
    fn adaptive_wait_clamps_and_ignores_garbage() {
        let mut w = AdaptiveWait::new();
        for _ in 0..100 {
            w.observe(0.0);
        }
        assert_eq!(w.cut_us(), ADAPTIVE_MIN_CUT_US);
        for _ in 0..200 {
            w.observe(1e7);
        }
        assert_eq!(w.cut_us(), ADAPTIVE_MAX_CUT_US);
        // NaN / negative observations are dropped, not absorbed
        let before = w.cut_us();
        w.observe(f64::NAN);
        w.observe(-5.0);
        assert_eq!(w.cut_us(), before);
    }

    // --------------------------------------------------------------- pool

    #[test]
    fn pool_assigns_workers_round_robin_and_sizes_shards() {
        // N=5 workers, M=2 rows, S=2 shards: shard 0 serves {0,2,4} (6
        // rows), shard 1 serves {1,3} (4 rows)
        let pool = InferencePool::new(InferencePoolCfg {
            workers: 5,
            rows_per_worker: 2,
            shards: 2,
            wait: WaitPolicy::Adaptive,
            epoch: EpochMode::Pool,
            obs_dim: 3,
            act_dim: 1,
        });
        assert_eq!(pool.shard_count(), 2);
        assert_eq!(pool.shards()[0].fleet_rows(), 6);
        assert_eq!(pool.shards()[1].fleet_rows(), 4);
        assert!(Arc::ptr_eq(pool.shard_for(0), pool.shard_for(2)));
        assert!(Arc::ptr_eq(pool.shard_for(1), pool.shard_for(3)));
        assert!(!Arc::ptr_eq(pool.shard_for(0), pool.shard_for(1)));

        // shard counts beyond N clamp (every shard must own >= 1 worker)
        let pool = InferencePool::new(InferencePoolCfg {
            workers: 2,
            rows_per_worker: 1,
            shards: 8,
            wait: WaitPolicy::Adaptive,
            epoch: EpochMode::Pool,
            obs_dim: 3,
            act_dim: 1,
        });
        assert_eq!(pool.shard_count(), 2);
    }

    /// Two shards serve disjoint worker subsets concurrently; the merged
    /// report accounts for the whole fleet.
    #[test]
    fn pool_serves_across_shards_and_merges_reports() {
        let n = 4;
        let ticks = 20;
        let f = factory(3, 1);
        let store = published_store(&f);
        let pool = Arc::new(InferencePool::new(InferencePoolCfg {
            workers: n,
            rows_per_worker: 1,
            shards: 2,
            wait: WaitPolicy::Fixed(Duration::from_millis(5_000)),
            epoch: EpochMode::Pool,
            obs_dim: 3,
            act_dim: 1,
        }));
        let clients: Vec<ActorClient> = (0..n).map(|w| pool.client(w)).collect();
        let mut server_hs = Vec::new();
        for shard in pool.shards() {
            let shard = shard.clone();
            let store2 = store.clone();
            server_hs.push(thread::spawn(move || {
                let f = factory(3, 1);
                shard.serve_ppo(&f, &store2)
            }));
        }
        let mut worker_hs = Vec::new();
        for (w, mut client) in clients.into_iter().enumerate() {
            worker_hs.push(thread::spawn(move || {
                let obs = vec![0.1 * (w as f32 + 1.0); 3];
                for _ in 0..ticks {
                    let resp = client.act(&obs, &[0.3]).unwrap();
                    assert_eq!(resp.action().len(), 1);
                }
            }));
        }
        for h in worker_hs {
            h.join().unwrap();
        }
        for h in server_hs {
            h.join().unwrap().unwrap();
        }
        let rep = pool.report();
        assert_eq!(rep.shards, 2);
        assert_eq!(rep.fleet_rows, n); // summed across shards
        assert_eq!(rep.rows, (n * ticks) as u64);
        // each shard coalesced its own 2 workers: 2 forwards per tick
        // fleet-wide (one per shard), never more
        assert!(rep.forwards <= (2 * ticks) as u64 + 2);
    }

    /// The per-shard straggler cut: a parked worker on shard 0 must not
    /// delay shard 1, and shard 0's own cut must still fire.
    #[test]
    fn pool_shard_timeout_cut_is_per_shard() {
        let f = factory(3, 1);
        let store = published_store(&f);
        let pool = Arc::new(InferencePool::new(InferencePoolCfg {
            workers: 4,
            rows_per_worker: 1,
            shards: 2,
            wait: WaitPolicy::Fixed(Duration::from_millis(40)),
            epoch: EpochMode::Pool,
            obs_dim: 3,
            act_dim: 1,
        }));
        // shard 0: workers 0 (active) and 2 (parked); shard 1: workers
        // 1 and 3, both active and in phase
        let mut c0 = pool.client(0);
        let mut c1 = pool.client(1);
        let _parked = pool.client(2);
        let mut c3 = pool.client(3);
        let mut server_hs = Vec::new();
        for shard in pool.shards() {
            let shard = shard.clone();
            let store2 = store.clone();
            server_hs.push(thread::spawn(move || {
                let f = factory(3, 1);
                shard.serve_ppo(&f, &store2)
            }));
        }

        // shard 1 dispatches as soon as both its workers are pending
        let h1 = thread::spawn(move || {
            let t0 = Instant::now();
            for _ in 0..5 {
                c1.act(&[0.1, 0.1, 0.1], &[0.0]).unwrap();
            }
            (t0.elapsed(), c1)
        });
        let h3 = thread::spawn(move || {
            for _ in 0..5 {
                c3.act(&[0.2, 0.2, 0.2], &[0.0]).unwrap();
            }
            c3
        });
        // shard 0's lone active worker needs the cut every tick
        let t0 = Instant::now();
        let resp = c0.act(&[0.3, 0.3, 0.3], &[0.0]).unwrap();
        let shard0_wait = t0.elapsed();
        drop(resp);
        assert!(shard0_wait >= Duration::from_millis(35), "{shard0_wait:?}");

        let (shard1_time, c1) = h1.join().unwrap();
        let c3 = h3.join().unwrap();
        // 5 in-phase ticks on shard 1 must beat ONE cut window on shard 0
        // (they never wait on the parked worker across the pool)
        assert!(
            shard1_time < shard0_wait,
            "shard 1 waited on shard 0's straggler: {shard1_time:?} vs {shard0_wait:?}"
        );
        drop(c0);
        drop(c1);
        drop(c3);
        drop(_parked);
        for h in server_hs {
            h.join().unwrap().unwrap();
        }
        let rep = pool.report();
        assert!(rep.timeout_dispatches >= 1, "shard 0 cut never fired");
        // >= 4, not 5: shard 1's very first tick may cut as a partial if
        // one worker thread spawns pathologically late
        assert!(rep.full_dispatches >= 4, "shard 1 did not coalesce");
    }

    // ------------------------------------------------------- epoch gate

    /// Tentpole: with the pool gate, a mid-run publish reaches every
    /// shard as ONE atomic epoch flip. No response anywhere in the pool
    /// pairs the old epoch with the new version (or vice versa), each
    /// worker's epoch sequence moves 1 -> 2 exactly once, and the gate
    /// records exactly one barrier flip.
    #[test]
    fn pool_epoch_gate_flips_all_shards_atomically() {
        use crate::runtime::epoch::EpochMode;

        let nf = factory(3, 1);
        let store = published_store(&nf);
        let pool = Arc::new(InferencePool::new(InferencePoolCfg {
            workers: 2,
            rows_per_worker: 1,
            shards: 2,
            wait: WaitPolicy::Fixed(Duration::from_millis(1)),
            epoch: EpochMode::Pool,
            obs_dim: 3,
            act_dim: 1,
        }));
        let clients: Vec<ActorClient> = (0..2).map(|w| pool.client(w)).collect();
        let mut server_hs = Vec::new();
        for shard in pool.shards() {
            let shard = shard.clone();
            let store2 = store.clone();
            server_hs.push(thread::spawn(move || {
                let f = factory(3, 1);
                shard.serve_ppo(&f, &store2)
            }));
        }
        // quiesce both workers at a barrier around the publish: with no
        // dispatch in flight when the proposal lands, EVERY post-barrier
        // dispatch pool-wide must already run under (epoch 2, version 2)
        // — any (1, 2) or (2, 1) pairing, or a late (1, 1), means a shard
        // dispatched around the flip barrier
        let quiesced = Arc::new(std::sync::Barrier::new(3));
        let resume = Arc::new(std::sync::Barrier::new(3));
        let mut worker_hs = Vec::new();
        for (w, mut client) in clients.into_iter().enumerate() {
            let quiesced = quiesced.clone();
            let resume = resume.clone();
            worker_hs.push(thread::spawn(move || {
                let obs = vec![0.1 * (w as f32 + 1.0); 3];
                for _ in 0..50 {
                    let resp = client.act(&obs, &[0.0]).unwrap();
                    assert_eq!((resp.epoch, resp.snapshot.version), (1, 1));
                }
                quiesced.wait(); // every pre-publish dispatch has drained
                resume.wait(); // main published while we were parked
                let mut seen = Vec::new();
                for _ in 0..50 {
                    let resp = client.act(&obs, &[0.0]).unwrap();
                    seen.push((resp.epoch, resp.snapshot.version));
                }
                seen
            }));
        }
        quiesced.wait();
        store.publish(nf.init_ppo_params(1), NormSnapshot::identity(3));
        resume.wait();
        let seens: Vec<Vec<(u64, u64)>> =
            worker_hs.into_iter().map(|h| h.join().unwrap()).collect();
        for h in server_hs {
            h.join().unwrap().unwrap();
        }
        assert_eq!(pool.epoch_gate().expect("pool mode has a gate").flips(), 1);
        for seen in &seens {
            assert_eq!(seen.len(), 50);
            assert!(
                seen.iter().all(|&ev| ev == (2, 2)),
                "a dispatch slipped around the flip barrier: {seen:?}"
            );
        }
    }

    // --------------------------------------------------- shard failure

    use crate::runtime::test_support::PanickingSharedFactory;

    /// Satellite acceptance: a serve-thread panic at N=2/S=2 kills ONE
    /// shard; its blocked worker unwinds with an error within the probe
    /// interval (no deadlock), the sibling shard keeps serving its own
    /// worker to completion, and the panicked thread's join reports the
    /// unwind.
    #[test]
    fn shard_panic_fails_blocked_clients_instead_of_hanging() {
        use crate::runtime::epoch::EpochMode;

        let nf = factory(3, 1);
        let store = published_store(&nf);
        let pool = Arc::new(InferencePool::new(InferencePoolCfg {
            workers: 2,
            rows_per_worker: 1,
            shards: 2,
            wait: WaitPolicy::Fixed(Duration::from_millis(1)),
            epoch: EpochMode::Pool,
            obs_dim: 3,
            act_dim: 1,
        }));
        let clients: Vec<ActorClient> = (0..2).map(|w| pool.client(w)).collect();
        let factory_shared = Arc::new(PanickingSharedFactory::new(factory(3, 1), 3));
        let mut server_hs = Vec::new();
        for shard in pool.shards() {
            let shard = shard.clone();
            let store2 = store.clone();
            let f2 = factory_shared.clone();
            server_hs.push(thread::spawn(move || shard.serve_ppo(f2.as_ref(), &store2)));
        }
        let mut worker_hs = Vec::new();
        for (w, mut client) in clients.into_iter().enumerate() {
            worker_hs.push(thread::spawn(move || {
                let obs = vec![0.1 * (w as f32 + 1.0); 3];
                for t in 0..50 {
                    if client.act(&obs, &[0.0]).is_err() {
                        return Err(t); // unwound instead of hanging
                    }
                }
                Ok(())
            }));
        }
        let results: Vec<Result<(), usize>> =
            worker_hs.into_iter().map(|h| h.join().unwrap()).collect();
        let joins: Vec<_> = server_hs.into_iter().map(|h| h.join()).collect();
        // exactly one worker hit the dead shard and errored out early
        assert_eq!(
            results.iter().filter(|r| r.is_err()).count(),
            1,
            "exactly one worker must observe the dead shard: {results:?}"
        );
        // the other ran its full 50 ticks on the surviving shard
        assert_eq!(results.iter().filter(|r| r.is_ok()).count(), 1);
        // one serve thread panicked, the sibling exited cleanly
        assert_eq!(joins.iter().filter(|j| j.is_err()).count(), 1);
        assert!(joins
            .iter()
            .any(|j| matches!(j, Ok(r) if r.is_ok())));
    }

    /// A panic inside backend CONSTRUCTION (before the serve loop even
    /// starts) must also fail clients — the down guard covers the whole
    /// serve entry point, not just the dispatch loop.
    #[test]
    fn construction_panic_fails_clients_instead_of_hanging() {
        let nf = factory(3, 1);
        let store = published_store(&nf);
        let srv = Arc::new(server(1, 10));
        let mut client = srv.client();
        let srv2 = srv.clone();
        let store2 = store.clone();
        let h = thread::spawn(move || {
            let f = PanickingSharedFactory::new(factory(3, 1), 0);
            srv2.serve_ppo(&f, &store2)
        });
        assert!(
            client.act(&[0.0, 0.0, 0.0], &[0.0]).is_err(),
            "client must unwind, not hang"
        );
        drop(client);
        assert!(h.join().is_err(), "serve thread must have panicked");
    }

    // ------------------------------------------------- chaos + respawn

    /// Chaos harness end-to-end on one shard: the armed cell panics the
    /// serve thread at its scripted dispatch, the blocked client errors
    /// out promptly, and re-serving the SAME server object (what the
    /// supervisor's respawn does) heals it — the lifetime dispatch
    /// counter keeps the spent cell from re-firing, and `revive` clears
    /// the down marker so the client's retry loop eventually succeeds.
    #[test]
    fn armed_fault_kills_dispatch_and_respawn_heals_the_shard() {
        let nf = factory(3, 1);
        let store = published_store(&nf);
        let srv = Arc::new(server(1, 10));
        let injected = Arc::new(AtomicU64::new(0));
        srv.arm_faults(vec![Arc::new(FaultCell::new(2))], injected.clone());
        let mut client = srv.client();

        let (srv2, store2) = (srv.clone(), store.clone());
        let h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });
        // dispatch 1 is clean; dispatch 2 trips the cell and the blocked
        // client unwinds with an error instead of hanging
        client.act(&[0.0, 0.0, 0.0], &[0.0]).unwrap();
        assert!(client.act(&[0.0, 0.0, 0.0], &[0.0]).is_err());
        assert!(h.join().is_err(), "fault must panic the serve thread");
        assert_eq!(injected.load(Ordering::SeqCst), 1);

        // supervisor respawn: same server, fresh serve thread
        let (srv2, store2) = (srv.clone(), store.clone());
        let h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });
        // the worker side retries (acts fail fast until the revive lands)
        let mut healed = false;
        for _ in 0..500 {
            if client.act(&[0.0, 0.0, 0.0], &[0.0]).is_ok() {
                healed = true;
                break;
            }
            thread::sleep(Duration::from_millis(2));
        }
        assert!(healed, "respawned shard must serve again");
        assert_eq!(
            injected.load(Ordering::SeqCst),
            1,
            "spent cell must not re-fire after the respawn"
        );
        drop(client);
        h.join().unwrap().unwrap();
    }

    /// Satellite: an abandoned lease (leased, partially filled, dropped
    /// without `act_leased`) must recycle its buffers — hot_allocs stays
    /// flat across abandon/re-lease cycles — and leaves no request
    /// behind, so the shard's dispatch cut serves the workers that DID
    /// submit instead of wedging on a phantom slab.
    #[test]
    fn abandoned_lease_recycles_buffers_and_does_not_wedge_dispatch() {
        let f = factory(3, 1);
        let store = published_store(&f);
        let srv = Arc::new(server(2, 30));
        let mut flaky = srv.client();
        let mut steady = srv.client();

        // warmup lease allocates; fill half the obs slab, then abandon
        {
            let mut lease = flaky.lease(1, true).unwrap();
            lease.obs_mut()[..2].copy_from_slice(&[0.5, -0.5]);
        }
        let after_first = srv.report().hot_allocs;
        assert!(after_first > 0, "warmup lease must have allocated");
        for _ in 0..20 {
            let mut lease = flaky.lease(1, true).unwrap();
            lease.obs_mut()[0] = 0.1;
            // dropped unsubmitted
        }
        assert_eq!(
            srv.report().hot_allocs,
            after_first,
            "abandoned leases must recycle, not leak-and-reallocate"
        );

        let (srv2, store2) = (srv.clone(), store.clone());
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });
        // flaky abandoned instead of submitting: steady's slab rides the
        // 30ms straggler cut as a partial batch, never a wedge
        let t0 = Instant::now();
        let resp = steady.act(&[0.2, 0.2, 0.2], &[0.0]).unwrap();
        assert_eq!(resp.action().len(), 1);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "dispatch cut wedged behind an abandoned lease: {:?}",
            t0.elapsed()
        );
        drop(resp);
        drop(flaky);
        drop(steady);
        server_h.join().unwrap().unwrap();
        let rep = srv.report();
        assert_eq!(rep.rows, 1, "only the submitted slab reached a forward");
        assert!(rep.timeout_dispatches >= 1, "the straggler cut must fire");
    }

    /// A registration lease keeps the serve loop alive through a
    /// zero-client window (a respawning worker re-registers moments
    /// later) and through client turnover; dropping the last hold lets
    /// the shard exit cleanly.
    #[test]
    fn hold_keeps_server_alive_through_zero_client_window() {
        let nf = factory(3, 1);
        let store = published_store(&nf);
        let srv = Arc::new(server(1, 10));
        let hold = srv.hold();
        let (srv2, store2) = (srv.clone(), store.clone());
        let h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });
        // no clients registered at all: the hold alone keeps it idling
        thread::sleep(Duration::from_millis(40));
        assert!(!h.is_finished(), "serve loop exited despite a live hold");
        // a late registration (the respawned worker) is served normally
        let mut client = srv.client();
        client.act(&[0.0, 0.0, 0.0], &[0.0]).unwrap();
        drop(client);
        thread::sleep(Duration::from_millis(40));
        assert!(!h.is_finished(), "hold must outlast individual clients");
        drop(hold);
        h.join().unwrap().unwrap();
    }

    /// A panic mid-dispatch (requests already gathered OUT of the queue)
    /// combined with an immediate revive must still fail the in-flight
    /// requests: the batch guard replies on unwind, so the client never
    /// waits on a slot nobody will fill even though `server_down` is
    /// cleared again before its next liveness probe.
    #[test]
    fn fast_revive_after_mid_dispatch_panic_does_not_strand_clients() {
        let nf = factory(3, 1);
        let store = published_store(&nf);
        let srv = Arc::new(server(1, 10));
        let injected = Arc::new(AtomicU64::new(0));
        srv.arm_faults(vec![Arc::new(FaultCell::new(1))], injected.clone());
        let mut client = srv.client();
        let hold = srv.hold();

        // respawn loop tighter than the client's 50ms liveness probe: the
        // down window is far too short for the probe to observe it
        let (srv2, store2) = (srv.clone(), store.clone());
        let supervisor = thread::spawn(move || loop {
            let f = factory(3, 1);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                srv2.serve_ppo(&f, &store2)
            }));
            match r {
                Ok(done) => break done,
                Err(_) => continue, // immediate respawn, no backoff
            }
        });

        // first act dies to the fault mid-dispatch; the retry succeeds on
        // the respawned serve thread
        let t0 = Instant::now();
        assert!(client.act(&[0.0, 0.0, 0.0], &[0.0]).is_err());
        let mut healed = false;
        for _ in 0..500 {
            if client.act(&[0.0, 0.0, 0.0], &[0.0]).is_ok() {
                healed = true;
                break;
            }
            thread::sleep(Duration::from_millis(2));
        }
        assert!(healed, "client must recover on the respawned shard");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "client stalled across the revive: {:?}",
            t0.elapsed()
        );
        assert_eq!(injected.load(Ordering::SeqCst), 1);
        drop(client);
        drop(hold);
        supervisor.join().unwrap().unwrap();
    }
}
