//! Shared inference server: one fleet-sized batched forward serves all N
//! sampler workers (`--inference-mode shared`).
//!
//! PR 1 vectorized each worker over M lockstep envs, but every worker
//! still ran its own private backend: N small forwards per sim tick
//! fleet-wide. This module centralizes policy evaluation the way
//! SEED-style systems and Spreeze do: a dedicated server thread owns ONE
//! `ActorBackend` sized to `N * M` rows, workers submit their M-row slabs
//! through an MPSC request queue via an [`ActorClient`] handle and block
//! on a per-client completion slot, and the server coalesces pending
//! slabs into one mega-batch forward.
//!
//! **Adaptive cut policy.** A dispatch fires when every active client has
//! a slab pending (the fleet is in phase: one forward per sim tick) OR
//! when `infer_max_wait_us` has elapsed since the first slab of the batch
//! arrived — so a straggler worker (env reset, episode bookkeeping, queue
//! backpressure, sync-mode parking) never stalls the rest of the fleet.
//!
//! **Policy refresh.** The server observes the [`PolicyStore`] once per
//! dispatch, so every row in a forward is evaluated under the same
//! parameter version, and each response carries the snapshot used. A
//! worker that sees the version move cuts its in-progress chunks before
//! appending the new tick (see `coordinator::sampler`), preserving the
//! one-policy-version-per-chunk invariant without any worker-side polling.
//!
//! **Normalization.** Clients submit *raw* observations; the server
//! normalizes them under the dispatch snapshot and returns the normalized
//! rows, so the obs recorded into experience chunks always match what the
//! policy actually saw. The native MLP forward is row-independent, which
//! makes shared-vs-local bitwise equivalence a testable property (see the
//! sampler tests), not an aspiration.
//!
//! Threading: backends are not `Send` on the XLA path, so [`InferenceServer::serve_ppo`]
//! / [`serve_ddpg`](InferenceServer::serve_ddpg) build the backend on the
//! calling thread (the orchestrator spawns one server thread per run) and
//! everything else communicates through `Mutex`/`Condvar` queues.

use crate::coordinator::metrics::InferenceReport;
use crate::coordinator::policy_store::{PolicySnapshot, PolicyStore};
use crate::runtime::{ActResult, ActorBackend, BackendFactory, DdpgActorBackend};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Static server configuration (derived from `TrainConfig`).
#[derive(Debug, Clone)]
pub struct InferenceServerCfg {
    /// Straggler cut: max wait from the first pending slab to dispatch.
    pub max_wait: Duration,
    /// Fleet capacity in rows (N workers x M envs per worker).
    pub fleet_rows: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
}

/// One policy evaluation answer for a single worker's slab.
pub struct ActResponse {
    /// This worker's rows only (actions/logp/value sliced out of the
    /// mega-batch result; DDPG fills `action` and zero logp/value).
    pub out: ActResult,
    /// The worker's obs normalized under `snapshot` ([rows * obs_dim]).
    pub norm_obs: Vec<f32>,
    /// The policy snapshot this forward used (same for every row of the
    /// dispatch — the one-version-per-forward guarantee).
    pub snapshot: Arc<PolicySnapshot>,
    /// This slab's row-proportional share of the server's CPU time for
    /// the dispatch (normalize + forward). Workers fold it into their
    /// busy-time accounting so the virtual-core rollout timing model
    /// stays honest when inference runs off-thread.
    pub server_busy_secs: f64,
}

/// Completion slot: SPSC — the server fills it, exactly one client waits.
struct ReplySlot {
    cell: Mutex<Option<Result<ActResponse, String>>>,
    ready: Condvar,
}

struct PendingReq {
    rows: usize,
    obs: Vec<f32>,
    /// [rows * act_dim] N(0,1) draws (PPO) or empty (DDPG deterministic).
    noise: Vec<f32>,
    enqueued: Instant,
    reply: Arc<ReplySlot>,
}

struct QueueState {
    pending: Vec<PendingReq>,
    pending_rows: usize,
    /// Arrival time of the oldest slab in the current batch window.
    first_enqueue: Option<Instant>,
    /// Live client handles; the server exits when this reaches zero.
    active_clients: usize,
    /// Set once the serve loop has exited: submits fail fast.
    server_down: bool,
}

struct ServerShared {
    cfg: InferenceServerCfg,
    q: Mutex<QueueState>,
    submitted: Condvar,
    metrics: Mutex<InferenceReport>,
}

/// Handle the orchestrator creates (one per run); `client()` handles go to
/// workers, `serve_*` runs on a dedicated thread.
pub struct InferenceServer {
    shared: Arc<ServerShared>,
}

/// Worker-side handle: submit one slab, block until the server's next
/// dispatch answers it. Dropping the handle deregisters the worker so the
/// server stops waiting for it (and exits once all clients are gone).
pub struct ActorClient {
    shared: Arc<ServerShared>,
    slot: Arc<ReplySlot>,
}

impl InferenceServer {
    pub fn new(cfg: InferenceServerCfg) -> InferenceServer {
        let fleet_rows = cfg.fleet_rows;
        InferenceServer {
            shared: Arc::new(ServerShared {
                cfg,
                q: Mutex::new(QueueState {
                    pending: Vec::new(),
                    pending_rows: 0,
                    first_enqueue: None,
                    active_clients: 0,
                    server_down: false,
                }),
                submitted: Condvar::new(),
                metrics: Mutex::new(InferenceReport::new(fleet_rows)),
            }),
        }
    }

    /// Register a worker and hand out its submission handle. Create every
    /// client BEFORE spawning the serve thread, or the server may observe
    /// zero active clients and exit immediately.
    pub fn client(&self) -> ActorClient {
        self.shared.q.lock().unwrap().active_clients += 1;
        ActorClient {
            shared: self.shared.clone(),
            slot: Arc::new(ReplySlot {
                cell: Mutex::new(None),
                ready: Condvar::new(),
            }),
        }
    }

    /// Snapshot of the dispatch statistics (valid any time; final after
    /// the serve thread exits).
    pub fn report(&self) -> InferenceReport {
        self.shared.metrics.lock().unwrap().clone()
    }

    /// Serve PPO `act` requests on the current thread until every client
    /// handle is dropped. Builds the fleet-sized backend here (backends
    /// are thread-local on the XLA path).
    pub fn serve_ppo(
        &self,
        factory: &dyn BackendFactory,
        store: &PolicyStore,
    ) -> anyhow::Result<()> {
        let actor = match factory.make_actor_shared(self.shared.cfg.fleet_rows) {
            Ok(a) => a,
            Err(e) => {
                self.fail_all(&format!("shared actor construction failed: {e:#}"));
                return Err(e);
            }
        };
        self.serve(ServerBackend::Ppo(actor), store)
    }

    /// DDPG counterpart of [`InferenceServer::serve_ppo`].
    pub fn serve_ddpg(
        &self,
        factory: &dyn BackendFactory,
        store: &PolicyStore,
    ) -> anyhow::Result<()> {
        let actor = match factory.make_ddpg_actor_shared(self.shared.cfg.fleet_rows) {
            Ok(a) => a,
            Err(e) => {
                self.fail_all(&format!("shared ddpg actor construction failed: {e:#}"));
                return Err(e);
            }
        };
        self.serve(ServerBackend::Ddpg(actor), store)
    }

    /// Mark the server down and fail every pending request (and all future
    /// submits). Called on any serve-loop exit path, including unwinds —
    /// so it must tolerate a poisoned queue lock (a panic mid-dispatch
    /// must not escalate to a double panic, it must release the fleet).
    fn fail_all(&self, msg: &str) {
        let mut q = self
            .shared
            .q
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        q.server_down = true;
        q.pending_rows = 0;
        q.first_enqueue = None;
        for req in q.pending.drain(..) {
            reply(&req.reply, Err(msg.to_string()));
        }
    }

    fn serve(&self, mut backend: ServerBackend, store: &PolicyStore) -> anyhow::Result<()> {
        // Unwind guard: if the serve loop panics (bad artifact shapes, a
        // backend bug), mark the server down and fail outstanding slabs —
        // otherwise every worker would spin on its completion slot forever
        // and the run would hang instead of erroring. Idempotent with the
        // explicit fail_all calls on clean exit paths.
        struct DownGuard<'a>(&'a InferenceServer);
        impl Drop for DownGuard<'_> {
            fn drop(&mut self) {
                self.0.fail_all("inference server terminated unexpectedly");
            }
        }
        let _guard = DownGuard(self);
        let sh = &*self.shared;
        let o = sh.cfg.obs_dim;
        let a = sh.cfg.act_dim;
        // fixed > 0: shape-specialized backend (XLA artifact); partial
        // dispatches are padded up to `fixed` with zero rows whose outputs
        // are dropped. fixed == 0: flexible backend, every forward carries
        // exactly the real rows (the native path — padding-free).
        let fixed = backend.fixed_batch();
        if fixed > 0 && fixed < sh.cfg.fleet_rows {
            let msg = format!(
                "shared backend batch {fixed} cannot hold the fleet's {} rows",
                sh.cfg.fleet_rows
            );
            self.fail_all(&msg);
            anyhow::bail!(msg);
        }
        let cap = if fixed > 0 {
            fixed.max(sh.cfg.fleet_rows)
        } else {
            sh.cfg.fleet_rows
        };
        let mut obs_buf = vec![0.0f32; cap * o];
        let mut noise_buf = vec![0.0f32; cap * a];

        loop {
            // ---- gather one batch under the adaptive cut policy --------
            let (batch, was_full) = {
                let mut q = sh.q.lock().unwrap();
                loop {
                    if q.pending.is_empty() {
                        if q.active_clients == 0 {
                            drop(q);
                            self.fail_all("inference server shut down");
                            return Ok(());
                        }
                        let (g, _) = sh
                            .submitted
                            .wait_timeout(q, Duration::from_millis(50))
                            .unwrap();
                        q = g;
                        continue;
                    }
                    let full = q.pending.len() >= q.active_clients
                        || q.pending_rows >= sh.cfg.fleet_rows;
                    let deadline = q.first_enqueue.expect("pending implies first_enqueue")
                        + sh.cfg.max_wait;
                    let now = Instant::now();
                    if full || now >= deadline {
                        q.pending_rows = 0;
                        q.first_enqueue = None;
                        break (std::mem::take(&mut q.pending), full);
                    }
                    let (g, _) = sh.submitted.wait_timeout(q, deadline - now).unwrap();
                    q = g;
                }
            };

            // ---- one policy observation per dispatch -------------------
            let snapshot = loop {
                match store.latest() {
                    Some(s) => break s,
                    // clients gate on the first publish, so this only
                    // spins in pathological test setups
                    None => std::thread::sleep(Duration::from_millis(1)),
                }
            };

            // ---- pack + normalize the mega-batch -----------------------
            let rows: usize = batch.iter().map(|r| r.rows).sum();
            let dispatched_at = Instant::now();
            let busy_t0 = crate::util::timer::thread_cpu_secs();
            debug_assert!(rows <= cap, "batch of {rows} rows exceeds capacity {cap}");
            let mut cursor = 0usize;
            for req in &batch {
                let n = req.rows * o;
                obs_buf[cursor * o..cursor * o + n].copy_from_slice(&req.obs);
                for r in 0..req.rows {
                    let row = &mut obs_buf[(cursor + r) * o..(cursor + r + 1) * o];
                    snapshot.norm.apply(row);
                }
                if !req.noise.is_empty() {
                    noise_buf[cursor * a..cursor * a + req.rows * a]
                        .copy_from_slice(&req.noise);
                }
                cursor += req.rows;
            }
            let fwd_rows = if fixed > 0 { fixed } else { rows };
            for z in &mut obs_buf[rows * o..fwd_rows * o] {
                *z = 0.0; // padding rows (fixed-batch backends only)
            }
            for z in &mut noise_buf[rows * a..fwd_rows * a] {
                *z = 0.0;
            }

            // ---- the one forward ---------------------------------------
            let result = backend.forward(
                &snapshot.params,
                &obs_buf[..fwd_rows * o],
                &noise_buf[..fwd_rows * a],
                fwd_rows,
                a,
            );
            let dispatch_busy = crate::util::timer::thread_cpu_secs() - busy_t0;

            // ---- metrics -----------------------------------------------
            {
                let mut m = sh.metrics.lock().unwrap();
                m.forwards += 1;
                m.rows += rows as u64;
                if was_full {
                    m.full_dispatches += 1;
                } else {
                    m.timeout_dispatches += 1;
                }
                m.dispatch_rows.record(rows as f64);
                m.fill_ratio.record(rows as f64 / sh.cfg.fleet_rows as f64);
                for req in &batch {
                    m.queue_wait_us
                        .record((dispatched_at - req.enqueued).as_secs_f64() * 1e6);
                }
            }

            // ---- scatter responses -------------------------------------
            match result {
                Ok(res) => {
                    let mut cursor = 0usize;
                    for req in batch {
                        let (r0, r1) = (cursor, cursor + req.rows);
                        reply(
                            &req.reply,
                            Ok(ActResponse {
                                out: ActResult {
                                    action: res.action[r0 * a..r1 * a].to_vec(),
                                    logp: res.logp[r0..r1].to_vec(),
                                    value: res.value[r0..r1].to_vec(),
                                    mean: res.mean[r0 * a..r1 * a].to_vec(),
                                },
                                norm_obs: obs_buf[r0 * o..r1 * o].to_vec(),
                                snapshot: snapshot.clone(),
                                server_busy_secs: dispatch_busy * req.rows as f64
                                    / rows as f64,
                            }),
                        );
                        cursor = r1;
                    }
                }
                Err(e) => {
                    // reply the error to every slab in the dispatch and
                    // keep serving: workers terminate themselves exactly
                    // like a local-backend act failure
                    let msg = format!("shared inference forward failed: {e:#}");
                    crate::log_error!("{msg}");
                    for req in batch {
                        reply(&req.reply, Err(msg.clone()));
                    }
                }
            }
        }
    }
}

fn reply(slot: &ReplySlot, r: Result<ActResponse, String>) {
    *slot.cell.lock().unwrap() = Some(r);
    slot.ready.notify_one();
}

impl ActorClient {
    /// Submit this worker's slab (raw obs, per-row noise) and block until
    /// the server's dispatch answers it. `noise` must hold `rows *
    /// act_dim` N(0,1) draws for PPO, or be empty for DDPG.
    pub fn act(&self, raw_obs: &[f32], noise: &[f32]) -> anyhow::Result<ActResponse> {
        let sh = &*self.shared;
        let o = sh.cfg.obs_dim;
        let a = sh.cfg.act_dim;
        anyhow::ensure!(
            !raw_obs.is_empty() && raw_obs.len() % o == 0,
            "client slab must be a whole number of obs rows"
        );
        let rows = raw_obs.len() / o;
        anyhow::ensure!(
            noise.is_empty() || noise.len() == rows * a,
            "noise must be empty (ddpg) or rows * act_dim"
        );
        anyhow::ensure!(
            rows <= sh.cfg.fleet_rows,
            "slab of {rows} rows exceeds fleet capacity {}",
            sh.cfg.fleet_rows
        );
        {
            let mut q = sh.q.lock().unwrap();
            anyhow::ensure!(!q.server_down, "inference server is down");
            let now = Instant::now();
            q.pending.push(PendingReq {
                rows,
                obs: raw_obs.to_vec(),
                noise: noise.to_vec(),
                enqueued: now,
                reply: self.slot.clone(),
            });
            q.pending_rows += rows;
            q.first_enqueue.get_or_insert(now);
        }
        sh.submitted.notify_all();

        // await the completion slot; periodically probe server liveness
        // (never hold the slot lock while probing — server replies while
        // holding the queue lock on its exit path)
        let mut cell = self.slot.cell.lock().unwrap();
        loop {
            if let Some(r) = cell.take() {
                return r.map_err(|e| anyhow::anyhow!(e));
            }
            let (g, _) = self
                .slot
                .ready
                .wait_timeout(cell, Duration::from_millis(50))
                .unwrap();
            cell = g;
            if cell.is_some() {
                continue;
            }
            drop(cell);
            if self.shared.q.lock().unwrap().server_down {
                let mut c = self.slot.cell.lock().unwrap();
                // the terminal reply may have landed in the gap
                if let Some(r) = c.take() {
                    return r.map_err(|e| anyhow::anyhow!(e));
                }
                anyhow::bail!("inference server terminated");
            }
            cell = self.slot.cell.lock().unwrap();
        }
    }
}

impl Drop for ActorClient {
    fn drop(&mut self) {
        // poison-tolerant: a worker unwinding past its client must still
        // deregister, or the server would wait on a dead peer forever
        let mut q = self
            .shared
            .q
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        q.active_clients = q.active_clients.saturating_sub(1);
        drop(q);
        // wake the server so it re-evaluates the full-batch condition
        // (remaining workers shouldn't wait max_wait for a dead peer)
        self.shared.submitted.notify_all();
    }
}

/// The server's view of a policy backend: PPO (stochastic, needs noise)
/// or DDPG (deterministic actor; logp/value are zero-filled).
enum ServerBackend {
    Ppo(Box<dyn ActorBackend>),
    Ddpg(Box<dyn DdpgActorBackend>),
}

impl ServerBackend {
    fn fixed_batch(&self) -> usize {
        match self {
            ServerBackend::Ppo(b) => b.batch(),
            ServerBackend::Ddpg(b) => b.batch(),
        }
    }

    fn forward(
        &mut self,
        params: &[f32],
        obs: &[f32],
        noise: &[f32],
        rows: usize,
        act_dim: usize,
    ) -> anyhow::Result<ActResult> {
        match self {
            ServerBackend::Ppo(b) => b.act(params, obs, noise),
            ServerBackend::Ddpg(b) => {
                let action = b.act(params, obs)?;
                anyhow::ensure!(
                    action.len() >= rows * act_dim,
                    "ddpg actor returned {} values for {} rows",
                    action.len(),
                    rows
                );
                Ok(ActResult {
                    mean: action.clone(),
                    action,
                    logp: vec![0.0; rows],
                    value: vec![0.0; rows],
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::normalizer::NormSnapshot;
    use crate::config::{DdpgCfg, PpoCfg};
    use crate::runtime::native_backend::NativeFactory;
    use std::thread;

    fn factory(obs: usize, act: usize) -> NativeFactory {
        NativeFactory::new(obs, act, &[8, 8], PpoCfg::default(), DdpgCfg::default())
    }

    fn server(fleet_rows: usize, max_wait_ms: u64) -> InferenceServer {
        InferenceServer::new(InferenceServerCfg {
            max_wait: Duration::from_millis(max_wait_ms),
            fleet_rows,
            obs_dim: 3,
            act_dim: 1,
        })
    }

    fn published_store(f: &NativeFactory) -> Arc<PolicyStore> {
        let store = Arc::new(PolicyStore::new());
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));
        store
    }

    /// The acceptance-criterion property: with all N workers in phase,
    /// the server issues exactly ONE forward per sim tick fleet-wide.
    #[test]
    fn in_phase_fleet_gets_one_forward_per_tick() {
        let n = 8;
        let ticks = 25;
        let f = factory(3, 1);
        let store = published_store(&f);
        let srv = Arc::new(server(n, 5_000)); // generous cut: never fires
        let clients: Vec<ActorClient> = (0..n).map(|_| srv.client()).collect();

        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });

        let mut worker_hs = Vec::new();
        for (w, client) in clients.into_iter().enumerate() {
            worker_hs.push(thread::spawn(move || {
                let obs = vec![0.1 * (w as f32 + 1.0); 3];
                let noise = vec![0.0f32; 1];
                for _ in 0..ticks {
                    let resp = client.act(&obs, &noise).unwrap();
                    assert_eq!(resp.out.action.len(), 1);
                    assert_eq!(resp.norm_obs, obs); // identity norm
                    assert_eq!(resp.snapshot.version, 1);
                }
            }));
        }
        for h in worker_hs {
            h.join().unwrap();
        }
        // all clients dropped inside the worker threads -> server exits
        server_h.join().unwrap().unwrap();

        let rep = srv.report();
        assert_eq!(
            rep.forwards, ticks as u64,
            "expected exactly one forward per tick"
        );
        assert_eq!(rep.rows, (n * ticks) as u64);
        assert_eq!(rep.full_dispatches, ticks as u64);
        assert_eq!(rep.timeout_dispatches, 0);
        assert!((rep.mean_fill() - 1.0).abs() < 1e-9);
    }

    /// The straggler guard: with one worker parked, the other's slab must
    /// dispatch as a partial batch once `max_wait` elapses.
    #[test]
    fn timeout_cut_dispatches_partial_batch_past_parked_worker() {
        let f = factory(3, 1);
        let store = published_store(&f);
        let srv = Arc::new(server(2, 30));
        let active = srv.client();
        let parked = srv.client(); // registered, never submits

        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });

        let t0 = Instant::now();
        let resp = active.act(&[0.1, 0.2, 0.3], &[0.0]).unwrap();
        let waited = t0.elapsed();
        assert_eq!(resp.out.action.len(), 1);
        assert!(
            waited >= Duration::from_millis(25),
            "dispatched before the cut: {waited:?}"
        );
        assert!(
            waited < Duration::from_secs(2),
            "straggler stalled the fleet: {waited:?}"
        );

        drop(active);
        drop(parked);
        server_h.join().unwrap().unwrap();
        let rep = srv.report();
        assert_eq!(rep.forwards, 1);
        assert_eq!(rep.timeout_dispatches, 1);
        assert_eq!(rep.full_dispatches, 0);
        assert!((rep.mean_fill() - 0.5).abs() < 1e-9);
        assert!(rep.queue_wait_us.mean() >= 25_000.0);
    }

    /// Batched results must equal per-worker local forwards row for row
    /// (the server adds no numerical perturbation).
    #[test]
    fn shared_rows_match_local_forward_bitwise() {
        let f = factory(3, 2);
        let store = Arc::new(PolicyStore::new());
        store.publish(f.init_ppo_params(3), NormSnapshot::identity(3));
        let srv = Arc::new(InferenceServer::new(InferenceServerCfg {
            max_wait: Duration::from_millis(500),
            fleet_rows: 4,
            obs_dim: 3,
            act_dim: 2,
        }));
        let c0 = srv.client();
        let c1 = srv.client();
        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 2);
            srv2.serve_ppo(&f, &store2)
        });

        let obs0 = vec![0.3, -0.1, 0.7, 0.2, 0.0, -0.5];
        let noise0 = vec![0.4, -0.2, 0.1, 0.9];
        let obs1 = vec![-0.9, 0.5, 0.05, 0.6, -0.3, 0.8];
        let noise1 = vec![-0.7, 0.3, 0.0, -0.1];
        let (o0c, n0c) = (obs0.clone(), noise0.clone());
        let h0 = thread::spawn(move || c0.act(&o0c, &n0c).unwrap());
        let (o1c, n1c) = (obs1.clone(), noise1.clone());
        let h1 = thread::spawn(move || c1.act(&o1c, &n1c).unwrap());
        let r0 = h0.join().unwrap();
        let r1 = h1.join().unwrap();
        server_h.join().unwrap().unwrap();

        let flat = f.init_ppo_params(3);
        let mut local = f.make_actor_batched(2).unwrap();
        let want0 = local.act(&flat, &obs0, &noise0).unwrap();
        let want1 = local.act(&flat, &obs1, &noise1).unwrap();
        assert_eq!(r0.out.action, want0.action);
        assert_eq!(r0.out.logp, want0.logp);
        assert_eq!(r0.out.value, want0.value);
        assert_eq!(r1.out.action, want1.action);
        assert_eq!(r1.out.logp, want1.logp);
        assert_eq!(r1.out.value, want1.value);
    }

    #[test]
    fn server_exits_when_all_clients_drop_and_rejects_late_submits() {
        let f = factory(3, 1);
        let store = published_store(&f);
        let srv = Arc::new(server(1, 10));
        let client = srv.client();
        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ppo(&f, &store2)
        });
        client.act(&[0.0, 0.0, 0.0], &[0.0]).unwrap();
        drop(client);
        server_h.join().unwrap().unwrap();
        // a client created after shutdown fails fast instead of hanging
        let late = srv.client();
        assert!(late.act(&[0.0, 0.0, 0.0], &[0.0]).is_err());
    }

    #[test]
    fn ddpg_requests_use_empty_noise_and_zero_logp() {
        let f = factory(3, 1);
        let store = Arc::new(PolicyStore::new());
        let (actor_params, _) = f.init_ddpg_params(0);
        store.publish(actor_params.clone(), NormSnapshot::identity(3));
        let srv = Arc::new(server(2, 20));
        let client = srv.client();
        let srv2 = srv.clone();
        let store2 = store.clone();
        let server_h = thread::spawn(move || {
            let f = factory(3, 1);
            srv2.serve_ddpg(&f, &store2)
        });
        let resp = client.act(&[0.2, -0.2, 0.4, 0.1, 0.3, -0.6], &[]).unwrap();
        assert_eq!(resp.out.action.len(), 2);
        assert_eq!(resp.out.logp, vec![0.0, 0.0]);
        assert_eq!(resp.out.value, vec![0.0, 0.0]);
        let mut local = f.make_ddpg_actor_batched(2).unwrap();
        let want = local
            .act(&actor_params, &[0.2, -0.2, 0.4, 0.1, 0.3, -0.6])
            .unwrap();
        assert_eq!(resp.out.action, want);
        drop(client);
        server_h.join().unwrap().unwrap();
    }

    #[test]
    fn client_validates_slab_shapes() {
        let srv = server(4, 10);
        let client = srv.client();
        // not a whole number of rows
        assert!(client.act(&[0.0, 0.0], &[]).is_err());
        // bad noise length
        assert!(client.act(&[0.0; 3], &[0.0, 0.0]).is_err());
        // slab larger than the fleet
        assert!(client.act(&[0.0; 15], &[]).is_err());
    }
}
