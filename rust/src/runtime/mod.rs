//! Runtime: the compute-backend abstraction the coordinator talks to.
//!
//! Two implementations of the same traits:
//! * `xla_backend::XlaFactory` (behind the `xla` feature, so a link
//!   would dangle in default builds) — loads the AOT HLO-text artifacts and
//!   executes them through the PJRT CPU client (the production path; the
//!   request path never touches Python).
//! * [`native_backend::NativeFactory`] — the pure-Rust mirror (`nn::`),
//!   artifact-free; used by `cargo test`, quickstarts, and as the oracle
//!   in XLA-vs-native parity tests.
//!
//! PJRT handles are not `Send` (raw pointers into xla_extension), so
//! backends are created *per thread* through a `Send + Sync` factory: each
//! sampler thread owns its own client + compiled executables. Compilation
//! happens once at worker startup, never on the hot path.
//!
//! ## Inference placement (`--inference-mode`)
//!
//! * **local** (default) — every sampler worker builds its own actor via
//!   [`BackendFactory::make_actor_batched`] and runs M-row forwards
//!   privately: N forwards per sim tick fleet-wide.
//! * **shared** — the orchestrator spawns an
//!   [`inference_server::InferencePool`] of `--infer-shards S` serve
//!   threads; worker `w` is statically assigned to shard `w % S`, each
//!   shard builds an actor sized to exactly its workers' rows via
//!   [`BackendFactory::make_actor_shared`] and coalesces their M-row
//!   slabs into one forward per sim tick (dispatching early under the
//!   `--infer-wait` straggler-cut policy — adaptive by default). Workers
//!   talk to their shard through `inference_server::ActorClient` handles
//!   whose request/response buffers are recycled, keeping the
//!   steady-state tick allocation-free.
//!
//! All modes and shard counts produce bitwise-identical per-env
//! trajectories under a fixed policy version (the MLP forward is
//! row-independent); shared mode trades a request/response hop for
//! mega-batch amortization, which wins once N small forwards per tick
//! dominate the rollout loop, and sharding keeps that win once a single
//! mega-batch forward saturates a core. Across version changes, the
//! pool-wide [`epoch::EpochGate`] (default, `--infer-epoch pool`) flips
//! every shard to a newly published snapshot on the same dispatch
//! boundary, so shard count stays a pure performance knob even while the
//! learner publishes mid-run.

pub mod artifacts;
pub mod checkpoint;
pub mod daemon;
pub mod epoch;
pub mod inference_server;
pub mod native_backend;
#[cfg(feature = "xla")]
pub mod xla_backend;

use crate::nn::mlp::PpoStats;

/// Output of one batched policy evaluation (mirrors the AOT `act` tuple).
#[derive(Debug, Clone)]
pub struct ActResult {
    /// [B*A] sampled actions (pre-clip).
    pub action: Vec<f32>,
    /// [B] log π(a|s).
    pub logp: Vec<f32>,
    /// [B] value estimates.
    pub value: Vec<f32>,
    /// [B*A] distribution means (deterministic action for eval).
    pub mean: Vec<f32>,
}

/// Policy evaluation for sampler workers (PPO Gaussian policy).
pub trait ActorBackend {
    /// Fixed batch the backend expects per call (XLA artifacts are shape-
    /// specialized). Callers must pass exactly `batch()` rows.
    fn batch(&self) -> usize;
    fn obs_dim(&self) -> usize;
    fn act_dim(&self) -> usize;

    /// Evaluate the policy: `obs` is [batch * obs_dim], `noise` is
    /// [batch * act_dim] of N(0,1) draws supplied by the caller's RNG.
    fn act(&mut self, flat: &[f32], obs: &[f32], noise: &[f32]) -> anyhow::Result<ActResult>;
}

/// Mutable PPO training state (flat params + Adam moments).
#[derive(Debug, Clone)]
pub struct PpoTrainState {
    pub flat: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// 1-based Adam step counter (incremented per train_step).
    pub t: u64,
}

impl PpoTrainState {
    pub fn new(flat: Vec<f32>) -> Self {
        let n = flat.len();
        Self {
            flat,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }
}

/// One PPO minibatch view (already padded to the backend's size; `mask`
/// zeroes padding rows exactly).
#[derive(Debug, Clone)]
pub struct PpoMinibatch<'a> {
    pub obs: &'a [f32],
    pub act: &'a [f32],
    pub old_logp: &'a [f32],
    pub adv: &'a [f32],
    pub ret: &'a [f32],
    pub mask: &'a [f32],
}

/// PPO learner operations.
pub trait PpoLearnerBackend {
    /// Fixed minibatch row count (0 = any size accepted).
    fn minibatch_size(&self) -> usize;

    /// One Adam minibatch step (forward + backward + update), in place.
    fn train_step(
        &mut self,
        state: &mut PpoTrainState,
        lr: f32,
        mb: &PpoMinibatch<'_>,
    ) -> anyhow::Result<PpoStats>;

    /// Gradient only (for sharded data-parallel learning, §6.2). Returns
    /// (grad[P], total_loss, n_valid_rows).
    fn grad(&mut self, flat: &[f32], mb: &PpoMinibatch<'_>) -> anyhow::Result<(Vec<f32>, f32, f32)>;

    /// Apply externally averaged gradients with one Adam step.
    fn apply_grads(
        &mut self,
        state: &mut PpoTrainState,
        grads: &[f32],
        lr: f32,
    ) -> anyhow::Result<()>;

    /// GAE through the backend (XLA: the L1 Pallas gae_scan artifact).
    /// `val` has T+1 entries (bootstrap last); returns (adv[T], ret[T]).
    fn gae(&mut self, rew: &[f32], val: &[f32], cont: &[f32])
        -> anyhow::Result<(Vec<f32>, Vec<f32>)>;
}

/// Mutable DDPG training state (four flat vectors + two Adam states).
#[derive(Debug, Clone)]
pub struct DdpgTrainState {
    pub actor: Vec<f32>,
    pub critic: Vec<f32>,
    pub targ_actor: Vec<f32>,
    pub targ_critic: Vec<f32>,
    pub am: Vec<f32>,
    pub av: Vec<f32>,
    pub cm: Vec<f32>,
    pub cv: Vec<f32>,
    pub t: u64,
}

impl DdpgTrainState {
    pub fn new(actor: Vec<f32>, critic: Vec<f32>) -> Self {
        let (pa, pc) = (actor.len(), critic.len());
        Self {
            targ_actor: actor.clone(),
            targ_critic: critic.clone(),
            actor,
            critic,
            am: vec![0.0; pa],
            av: vec![0.0; pa],
            cm: vec![0.0; pc],
            cv: vec![0.0; pc],
            t: 0,
        }
    }
}

/// One DDPG replay minibatch view.
#[derive(Debug, Clone)]
pub struct DdpgBatch<'a> {
    pub obs: &'a [f32],
    pub act: &'a [f32],
    pub rew: &'a [f32],
    pub next_obs: &'a [f32],
    pub done: &'a [f32],
}

/// DDPG actor evaluation (sampler side; exploration noise added by caller).
pub trait DdpgActorBackend {
    fn batch(&self) -> usize;
    /// Deterministic actor: obs [batch*obs_dim] -> action [batch*act_dim].
    fn act(&mut self, actor: &[f32], obs: &[f32]) -> anyhow::Result<Vec<f32>>;
}

/// DDPG learner operations.
pub trait DdpgLearnerBackend {
    fn batch_size(&self) -> usize;
    /// One fused update (critic TD step, actor DPG step, Polyak targets).
    /// Returns (q_loss, pi_loss).
    fn train_step(
        &mut self,
        state: &mut DdpgTrainState,
        lr_actor: f32,
        lr_critic: f32,
        batch: &DdpgBatch<'_>,
    ) -> anyhow::Result<(f32, f32)>;
}

// --------------------------------------------- unified row-actor adapters

/// Adapts a deterministic [`DdpgActorBackend`] to the unified
/// [`ActorBackend`] row interface the generic sampler loop and the eval
/// path speak: the policy-noise lane is ignored (deterministic actors
/// draw no per-row noise) and the stochastic lanes come back empty —
/// `logp`/`value`/`mean` are `Vec::new()`, which algorithm hooks that
/// wrap this adapter (DDPG, TD3) never read.
pub struct DeterministicRowActor {
    inner: Box<dyn DdpgActorBackend>,
    obs_dim: usize,
    act_dim: usize,
}

impl DeterministicRowActor {
    pub fn new(inner: Box<dyn DdpgActorBackend>, obs_dim: usize, act_dim: usize) -> Self {
        Self {
            inner,
            obs_dim,
            act_dim,
        }
    }
}

impl ActorBackend for DeterministicRowActor {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.act_dim
    }

    fn act(&mut self, flat: &[f32], obs: &[f32], _noise: &[f32]) -> anyhow::Result<ActResult> {
        let action = self.inner.act(flat, obs)?;
        Ok(ActResult {
            action,
            logp: Vec::new(),
            value: Vec::new(),
            mean: Vec::new(),
        })
    }
}

// ------------------------------------------------- shared-inference view

/// The shared-inference shard's view of a policy backend: one batched
/// forward over the packed mega-batch. Implementations adapt
/// algorithm-specific backends so `runtime::inference_server` never
/// matches on a concrete algorithm — a new algorithm plugs in through
/// `algo::api::Algorithm::make_server_actor` with zero server edits.
pub trait ServerActor {
    /// Fixed rows per forward (shape-specialized XLA artifacts); 0 = the
    /// backend accepts any row count and the server dispatches
    /// padding-free.
    fn fixed_batch(&self) -> usize;

    /// Run ONE forward over `obs` (the packed, already-normalized
    /// mega-batch, padded to the fixed batch by the caller) under the
    /// given policy snapshot — f32 `snapshot.params` by default, or the
    /// int8 `snapshot.quant` payload when the publish-time quantizer
    /// attached one. `rows` is the real row count. Empty
    /// `logp`/`value`/`mean` lanes in the result signal a deterministic
    /// actor; the server zero-fills those per-slab lanes and reuses the
    /// action rows as the mean on scatter.
    fn forward(
        &mut self,
        snapshot: &crate::coordinator::policy_store::PolicySnapshot,
        obs: &[f32],
        noise: &[f32],
        rows: usize,
        act_dim: usize,
    ) -> anyhow::Result<ActResult>;
}

/// [`ServerActor`] over a stochastic policy (PPO Gaussian actor): the
/// noise lanes carry the workers' per-row N(0,1) draws. Dispatches to the
/// int8 snapshot when the publish pipeline attached one.
pub struct StochasticServerActor(pub Box<dyn ActorBackend>);

impl ServerActor for StochasticServerActor {
    fn fixed_batch(&self) -> usize {
        self.0.batch()
    }

    fn forward(
        &mut self,
        snapshot: &crate::coordinator::policy_store::PolicySnapshot,
        obs: &[f32],
        noise: &[f32],
        _rows: usize,
        _act_dim: usize,
    ) -> anyhow::Result<ActResult> {
        if let Some(q) = &snapshot.quant {
            // int8 path: flexible row count (config validation pins int8
            // to the native backend, so `fixed_batch` is 0 and `obs`
            // carries exactly the real rows — no padding to skip)
            let out = q.forward_stochastic(obs, noise);
            return Ok(ActResult {
                action: out.action,
                logp: out.logp,
                value: out.value,
                mean: out.mean,
            });
        }
        self.0.act(&snapshot.params, obs, noise)
    }
}

/// [`ServerActor`] over a deterministic actor (DDPG/TD3): noise lanes
/// are empty, and the empty `logp`/`value`/`mean` result lanes tell the
/// scatter stage to zero-fill. Dispatches to the int8 snapshot when the
/// publish pipeline attached one.
pub struct DeterministicServerActor(pub Box<dyn DdpgActorBackend>);

impl ServerActor for DeterministicServerActor {
    fn fixed_batch(&self) -> usize {
        self.0.batch()
    }

    fn forward(
        &mut self,
        snapshot: &crate::coordinator::policy_store::PolicySnapshot,
        obs: &[f32],
        _noise: &[f32],
        rows: usize,
        act_dim: usize,
    ) -> anyhow::Result<ActResult> {
        let action = if let Some(q) = &snapshot.quant {
            q.forward_deterministic(obs)
        } else {
            self.0.act(&snapshot.params, obs)?
        };
        anyhow::ensure!(
            action.len() >= rows * act_dim,
            "deterministic actor returned {} values for {} rows",
            action.len(),
            rows
        );
        Ok(ActResult {
            action,
            logp: Vec::new(),
            value: Vec::new(),
            mean: Vec::new(),
        })
    }
}

/// Build the factory selected by a run config: `Backend::Xla` loads the
/// preset's AOT artifacts; `Backend::Native` mirrors them in pure Rust.
pub fn make_factory(
    cfg: &crate::config::TrainConfig,
) -> anyhow::Result<Box<dyn BackendFactory>> {
    let (obs_dim, act_dim) = crate::env::registry::env_dims(&cfg.env)
        .ok_or_else(|| anyhow::anyhow!("unknown env {:?}", cfg.env))?;
    match cfg.backend {
        #[cfg(feature = "xla")]
        crate::config::Backend::Xla => Ok(Box::new(xla_backend::XlaFactory::new(
            &cfg.artifacts_dir,
            &cfg.env,
        )?)),
        #[cfg(not(feature = "xla"))]
        crate::config::Backend::Xla => anyhow::bail!(
            "this build has no XLA/PJRT support — rebuild with `--features xla` \
             (the native backend runs everywhere: `--backend native`)"
        ),
        crate::config::Backend::Native => Ok(Box::new(native_backend::NativeFactory::new(
            obs_dim,
            act_dim,
            &cfg.hidden,
            cfg.ppo.clone(),
            cfg.ddpg.clone(),
        ))),
    }
}

/// Per-thread backend construction. The factory is shared across workers
/// (`Send + Sync`); the backends it makes are thread-local.
pub trait BackendFactory: Send + Sync {
    fn obs_dim(&self) -> usize;
    fn act_dim(&self) -> usize;
    /// Total flat parameter count for the PPO nets.
    fn ppo_param_count(&self) -> usize;
    /// Fresh PPO parameters (Glorot / zeros / const per layout).
    fn init_ppo_params(&self, seed: u64) -> Vec<f32>;
    /// Fresh DDPG (actor, critic) parameters.
    fn init_ddpg_params(&self, seed: u64) -> (Vec<f32>, Vec<f32>);

    fn make_actor(&self) -> anyhow::Result<Box<dyn ActorBackend>>;
    fn make_ppo_learner(&self) -> anyhow::Result<Box<dyn PpoLearnerBackend>>;
    fn make_ddpg_actor(&self) -> anyhow::Result<Box<dyn DdpgActorBackend>>;
    fn make_ddpg_learner(&self) -> anyhow::Result<Box<dyn DdpgLearnerBackend>>;

    /// Build an actor sized for exactly `batch` rows per call, so the
    /// vectorized sampler's forward is full — no zero padding. Backends
    /// with shape-specialized executables (XLA) return their fixed-batch
    /// actor after checking it can hold `batch` real rows; the sampler
    /// pads only the difference.
    fn make_actor_batched(&self, batch: usize) -> anyhow::Result<Box<dyn ActorBackend>> {
        let _ = batch;
        self.make_actor()
    }

    /// DDPG counterpart of [`BackendFactory::make_actor_batched`].
    fn make_ddpg_actor_batched(
        &self,
        batch: usize,
    ) -> anyhow::Result<Box<dyn DdpgActorBackend>> {
        let _ = batch;
        self.make_ddpg_actor()
    }

    /// Build a fleet-slice actor for one shared-inference shard: it must
    /// accept ANY row count from 1 to `max_rows` per call (dispatch sizes
    /// vary with the straggler cut). `max_rows` is the shard's capacity —
    /// its assigned workers x M envs, NOT the whole fleet — so each of
    /// the pool's S shards gets an exactly-sized actor. Flexible backends
    /// (native, `batch() == 0`) serve every dispatch padding-free; shape-
    /// specialized backends (XLA) return the smallest emitted artifact
    /// holding `max_rows` rows (see `artifacts::PresetMeta::act_artifact_for`)
    /// and the server zero-pads partial dispatches.
    fn make_actor_shared(&self, max_rows: usize) -> anyhow::Result<Box<dyn ActorBackend>> {
        let _ = max_rows;
        self.make_actor()
    }

    /// DDPG counterpart of [`BackendFactory::make_actor_shared`].
    fn make_ddpg_actor_shared(
        &self,
        max_rows: usize,
    ) -> anyhow::Result<Box<dyn DdpgActorBackend>> {
        let _ = max_rows;
        self.make_ddpg_actor()
    }

    /// Build a SAC actor accepting up to `rows` rows per call (`rows` is a
    /// sizing hint; flexible backends ignore it). The default bails: SAC
    /// has no AOT/XLA artifacts yet, so only the native backend overrides
    /// this (config validation rejects `--algo sac --backend xla` before a
    /// factory is ever asked).
    fn make_sac_actor(&self, rows: usize) -> anyhow::Result<Box<dyn ActorBackend>> {
        let _ = rows;
        anyhow::bail!("this backend has no SAC actor (SAC runs native-only)")
    }

    /// Fresh SAC `(actor, critic1, critic2)` parameters. The actor head is
    /// `2 * act_dim` wide (per-dim mean ++ log-std); the twin critics share
    /// the DDPG critic layout. Default bails like
    /// [`BackendFactory::make_sac_actor`].
    fn init_sac_params(&self, seed: u64) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let _ = seed;
        anyhow::bail!("this backend cannot initialize SAC parameters (SAC runs native-only)")
    }
}

/// Fault-injection scaffolding shared by the inference-pool and
/// orchestrator test suites (unit tests only — never compiled into the
/// library proper).
#[cfg(test)]
pub(crate) mod test_support {
    use super::{ActResult, ActorBackend, BackendFactory, DdpgActorBackend};
    use crate::runtime::native_backend::NativeFactory;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Wraps the native factory so the FIRST shard to build its shared
    /// actor gets one that panics after `calls_before_panic` forwards
    /// (0 = panic inside construction itself). Later builders get healthy
    /// actors — the one-dead-shard scenario the failure-containment tests
    /// exercise.
    pub struct PanickingSharedFactory {
        inner: NativeFactory,
        built: AtomicUsize,
        calls_before_panic: usize,
    }

    impl PanickingSharedFactory {
        pub fn new(inner: NativeFactory, calls_before_panic: usize) -> Self {
            Self {
                inner,
                built: AtomicUsize::new(0),
                calls_before_panic,
            }
        }
    }

    struct PanicAfter {
        inner: Box<dyn ActorBackend>,
        left: usize,
    }

    impl ActorBackend for PanicAfter {
        fn batch(&self) -> usize {
            self.inner.batch()
        }
        fn obs_dim(&self) -> usize {
            self.inner.obs_dim()
        }
        fn act_dim(&self) -> usize {
            self.inner.act_dim()
        }
        fn act(&mut self, flat: &[f32], obs: &[f32], noise: &[f32]) -> anyhow::Result<ActResult> {
            if self.left == 0 {
                panic!("injected shard backend panic");
            }
            self.left -= 1;
            self.inner.act(flat, obs, noise)
        }
    }

    impl BackendFactory for PanickingSharedFactory {
        fn obs_dim(&self) -> usize {
            self.inner.obs_dim()
        }
        fn act_dim(&self) -> usize {
            self.inner.act_dim()
        }
        fn ppo_param_count(&self) -> usize {
            self.inner.ppo_param_count()
        }
        fn init_ppo_params(&self, seed: u64) -> Vec<f32> {
            self.inner.init_ppo_params(seed)
        }
        fn init_ddpg_params(&self, seed: u64) -> (Vec<f32>, Vec<f32>) {
            self.inner.init_ddpg_params(seed)
        }
        fn make_actor(&self) -> anyhow::Result<Box<dyn ActorBackend>> {
            self.inner.make_actor()
        }
        fn make_ppo_learner(&self) -> anyhow::Result<Box<dyn super::PpoLearnerBackend>> {
            self.inner.make_ppo_learner()
        }
        fn make_ddpg_actor(&self) -> anyhow::Result<Box<dyn DdpgActorBackend>> {
            self.inner.make_ddpg_actor()
        }
        fn make_ddpg_learner(&self) -> anyhow::Result<Box<dyn super::DdpgLearnerBackend>> {
            self.inner.make_ddpg_learner()
        }
        fn make_actor_shared(&self, max_rows: usize) -> anyhow::Result<Box<dyn ActorBackend>> {
            let first = self.built.fetch_add(1, Ordering::SeqCst) == 0;
            if first && self.calls_before_panic == 0 {
                panic!("injected construction panic");
            }
            let inner = self.inner.make_actor_shared(max_rows)?;
            Ok(if first {
                Box::new(PanicAfter {
                    inner,
                    left: self.calls_before_panic,
                })
            } else {
                inner
            })
        }
    }
}
