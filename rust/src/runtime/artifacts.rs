//! AOT artifact metadata: loads `artifacts/<preset>/meta.json` (written by
//! `python/compile/aot.py`), reconstructs the flat-parameter layout, and
//! cross-checks it against the native `nn::layout` — any drift between the
//! Python and Rust layout definitions fails loudly at startup instead of
//! silently mis-slicing parameters.

use crate::nn::layout::{self, Init, ParamEntry, ParamLayout};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// DDPG-specific metadata (present when the preset emits DDPG artifacts).
#[derive(Debug, Clone)]
pub struct DdpgMeta {
    pub batch: usize,
    pub gamma: f32,
    pub tau: f32,
    pub actor_layout: ParamLayout,
    pub critic_layout: ParamLayout,
}

/// Parsed per-preset artifact metadata.
#[derive(Debug, Clone)]
pub struct PresetMeta {
    pub preset: String,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub hidden: Vec<usize>,
    pub act_batch: usize,
    /// Every batch size a shape-specialized `act` artifact was emitted
    /// for (`act` covers `act_batch`; `act_b{B}` covers each other B).
    /// Lets the runtime pick a padding-free executable for any
    /// `envs_per_sampler` / shared-inference shard size (older meta.json
    /// without the field falls back to `[act_batch]`).
    pub act_batches: Vec<usize>,
    pub eval_batch: usize,
    pub minibatch: usize,
    pub horizon: usize,
    pub gamma: f32,
    pub lam: f32,
    pub clip: f32,
    pub ent_coef: f32,
    pub vf_coef: f32,
    pub param_count: usize,
    pub layout: ParamLayout,
    pub ddpg: Option<DdpgMeta>,
    /// artifact name -> absolute path
    artifact_paths: std::collections::BTreeMap<String, PathBuf>,
}

impl PresetMeta {
    /// Load `<dir>/<preset>/meta.json`.
    pub fn load(artifacts_dir: &str, preset: &str) -> Result<PresetMeta> {
        let dir = Path::new(artifacts_dir);
        let meta_path = dir.join(preset).join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} (run `make artifacts`?)"))?;
        let j = Json::parse(&text).context("parsing meta.json")?;

        let layout = parse_layout(j.get("params")?)?;
        let act_batch = j.get("act_batch")?.as_usize()?;
        let mut act_batches = match j.opt("act_batches") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|b| b.as_usize())
                .collect::<std::result::Result<Vec<_>, _>>()?,
            None => vec![act_batch],
        };
        if !act_batches.contains(&act_batch) {
            act_batches.push(act_batch);
        }
        act_batches.sort_unstable();
        act_batches.dedup();
        let meta = PresetMeta {
            preset: j.get("preset")?.as_str()?.to_string(),
            obs_dim: j.get("obs_dim")?.as_usize()?,
            act_dim: j.get("act_dim")?.as_usize()?,
            hidden: j
                .get("hidden")?
                .as_arr()?
                .iter()
                .map(|h| h.as_usize())
                .collect::<std::result::Result<_, _>>()?,
            act_batch,
            act_batches,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            minibatch: j.get("minibatch")?.as_usize()?,
            horizon: j.get("horizon")?.as_usize()?,
            gamma: j.get("gamma")?.as_f32()?,
            lam: j.get("lam")?.as_f32()?,
            clip: j.get("clip")?.as_f32()?,
            ent_coef: j.get("ent_coef")?.as_f32()?,
            vf_coef: j.get("vf_coef")?.as_f32()?,
            param_count: j.get("param_count")?.as_usize()?,
            layout,
            ddpg: match j.opt("ddpg") {
                None => None,
                Some(d) => Some(DdpgMeta {
                    batch: d.get("batch")?.as_usize()?,
                    gamma: d.get("gamma")?.as_f32()?,
                    tau: d.get("tau")?.as_f32()?,
                    actor_layout: parse_layout(d.get("actor_params")?)?,
                    critic_layout: parse_layout(d.get("critic_params")?)?,
                }),
            },
            artifact_paths: j
                .get("artifacts")?
                .as_obj()?
                .iter()
                .map(|(k, v)| Ok((k.clone(), dir.join(v.as_str()?))))
                .collect::<Result<_>>()?,
        };
        meta.cross_check()?;
        Ok(meta)
    }

    /// Absolute path of one artifact (e.g. "act", "train_ppo", "gae").
    pub fn artifact(&self, name: &str) -> Result<&Path> {
        let p = self
            .artifact_paths
            .get(name)
            .ok_or_else(|| anyhow!("preset {} has no artifact {name:?}", self.preset))?;
        if !p.exists() {
            return Err(anyhow!("artifact file missing: {p:?} (run `make artifacts`)"));
        }
        Ok(p)
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_paths.contains_key(name)
    }

    /// Pick the `act`-family artifact (`prefix` = "act" or "act_ddpg")
    /// for `rows` real rows: an exact-batch artifact first (padding-free
    /// forward), else the smallest emitted batch that holds `rows` (the
    /// caller zero-pads the difference). Returns (artifact name, batch).
    pub fn act_artifact_for(&self, prefix: &str, rows: usize) -> Result<(String, usize)> {
        let candidate = |b: usize| -> Option<String> {
            let name = if b == self.act_batch {
                prefix.to_string()
            } else {
                format!("{prefix}_b{b}")
            };
            self.has_artifact(&name).then_some(name)
        };
        if let Some(name) = candidate(rows) {
            return Ok((name, rows));
        }
        for &b in &self.act_batches {
            // ascending: first fit is the smallest (least padding)
            if b >= rows {
                if let Some(name) = candidate(b) {
                    return Ok((name, b));
                }
            }
        }
        Err(anyhow!(
            "no {prefix} artifact holds {rows} rows for preset {} (emitted batches \
             {:?}) — rebuild artifacts with a larger act batch \
             (python/compile/aot.py, Preset.act_batches)",
            self.preset,
            self.act_batches
        ))
    }

    /// Every emitted `prefix` bucket up to (and including) the smallest
    /// batch that holds `max_rows`, ascending `(artifact name, batch)`.
    /// The shared-inference shard compiles ALL of them and picks the
    /// smallest fit per dispatch, so a straggler-cut partial batch pads
    /// to the nearest bucket instead of the full shard capacity.
    pub fn act_buckets_for(&self, prefix: &str, max_rows: usize) -> Result<Vec<(String, usize)>> {
        let (_, cap) = self.act_artifact_for(prefix, max_rows)?;
        let mut out = Vec::new();
        for &b in &self.act_batches {
            if b > cap {
                break;
            }
            let name = if b == self.act_batch {
                prefix.to_string()
            } else {
                format!("{prefix}_b{b}")
            };
            if self.has_artifact(&name) {
                out.push((name, b));
            }
        }
        Ok(out)
    }

    /// Largest row count any emitted `prefix` artifact can hold — the
    /// ceiling on a shared-inference shard's capacity on the XLA path.
    /// With `--infer-shards S`, each shard needs an artifact for
    /// `ceil(N/S) * M` rows, so raising S is the way to serve fleets
    /// beyond the largest emitted act batch without re-running aot.py.
    pub fn max_act_rows(&self, prefix: &str) -> usize {
        self.act_batches
            .iter()
            .rev()
            .copied()
            .find(|&b| {
                let name = if b == self.act_batch {
                    prefix.to_string()
                } else {
                    format!("{prefix}_b{b}")
                };
                self.has_artifact(&name)
            })
            .unwrap_or(0)
    }

    /// Verify the Python-exported layout equals the native construction —
    /// both sides must agree byte-for-byte on the flat-parameter ABI.
    fn cross_check(&self) -> Result<()> {
        let native = layout::ppo_layout(self.obs_dim, self.act_dim, &self.hidden);
        if native != self.layout {
            return Err(anyhow!(
                "flat-param layout drift between python meta.json and nn::layout \
                 for preset {} — rebuild artifacts or fix the layout mirror",
                self.preset
            ));
        }
        if native.total() != self.param_count {
            return Err(anyhow!(
                "param_count mismatch: meta {} vs native {}",
                self.param_count,
                native.total()
            ));
        }
        if let Some(d) = &self.ddpg {
            let na = layout::actor_layout(self.obs_dim, self.act_dim, &self.hidden);
            let nc = layout::critic_layout(self.obs_dim, self.act_dim, &self.hidden);
            if na != d.actor_layout || nc != d.critic_layout {
                return Err(anyhow!("DDPG layout drift for preset {}", self.preset));
            }
        }
        Ok(())
    }
}

fn parse_layout(j: &Json) -> Result<ParamLayout> {
    let entries = j
        .as_arr()?
        .iter()
        .map(|e| {
            Ok(ParamEntry {
                name: e.get("name")?.as_str()?.to_string(),
                shape: e
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<std::result::Result<_, _>>()?,
                offset: e.get("offset")?.as_usize()?,
                init: Init::parse(e.get("init")?.as_str()?)
                    .ok_or_else(|| anyhow!("bad init {:?}", e.get("init")))?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ParamLayout { entries })
}

/// List presets available in an artifacts directory (via index.json).
pub fn list_presets(artifacts_dir: &str) -> Result<Vec<String>> {
    let text = std::fs::read_to_string(Path::new(artifacts_dir).join("index.json"))
        .with_context(|| format!("reading {artifacts_dir}/index.json"))?;
    let j = Json::parse(&text)?;
    Ok(j.as_obj()?.keys().cloned().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have run; they are the contract
    /// check between the Python emitter and the Rust loader.
    fn artifacts_available() -> bool {
        Path::new("artifacts/index.json").exists()
    }

    #[test]
    fn loads_all_indexed_presets() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        for preset in list_presets("artifacts").unwrap() {
            let meta = PresetMeta::load("artifacts", &preset).unwrap();
            assert_eq!(meta.preset, preset);
            assert!(meta.param_count > 0);
            assert!(meta.artifact("act").is_ok());
            assert!(meta.artifact("train_ppo").is_ok());
            assert!(meta.artifact("gae").is_ok());
        }
    }

    #[test]
    fn pendulum_meta_matches_native_layout() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let meta = PresetMeta::load("artifacts", "pendulum").unwrap();
        assert_eq!(meta.obs_dim, 3);
        assert_eq!(meta.act_dim, 1);
        assert!(meta.ddpg.is_some());
        let native = layout::ppo_layout(3, 1, &meta.hidden);
        assert_eq!(native, meta.layout);
    }

    /// Synthetic meta (no artifacts dir needed): batch selection must
    /// prefer an exact per-M artifact and otherwise pad on the smallest
    /// emitted batch that fits.
    #[test]
    fn act_artifact_selection_prefers_exact_then_smallest_fit() {
        let meta = PresetMeta {
            preset: "synthetic".into(),
            obs_dim: 3,
            act_dim: 1,
            hidden: vec![8, 8],
            act_batch: 1,
            act_batches: vec![1, 4, 16],
            eval_batch: 32,
            minibatch: 256,
            horizon: 256,
            gamma: 0.99,
            lam: 0.95,
            clip: 0.2,
            ent_coef: 0.0,
            vf_coef: 0.5,
            param_count: layout::ppo_layout(3, 1, &[8, 8]).total(),
            layout: layout::ppo_layout(3, 1, &[8, 8]),
            ddpg: None,
            artifact_paths: [("act", "p/act"), ("act_b4", "p/act_b4"), ("act_b16", "p/act_b16")]
                .into_iter()
                .map(|(k, v)| (k.to_string(), PathBuf::from(v)))
                .collect(),
        };
        // exact hits are padding-free
        assert_eq!(meta.act_artifact_for("act", 1).unwrap(), ("act".into(), 1));
        assert_eq!(
            meta.act_artifact_for("act", 4).unwrap(),
            ("act_b4".into(), 4)
        );
        // 3 rows pad into the b4 artifact, 9 into b16
        assert_eq!(
            meta.act_artifact_for("act", 3).unwrap(),
            ("act_b4".into(), 4)
        );
        assert_eq!(
            meta.act_artifact_for("act", 9).unwrap(),
            ("act_b16".into(), 16)
        );
        // beyond every emitted batch: actionable error
        let err = meta.act_artifact_for("act", 17).unwrap_err();
        assert!(format!("{err:#}").contains("rebuild artifacts"));
        // bucket ladders stop at the smallest batch that fits max_rows
        assert_eq!(
            meta.act_buckets_for("act", 9).unwrap(),
            vec![("act".into(), 1), ("act_b4".into(), 4), ("act_b16".into(), 16)]
        );
        assert_eq!(
            meta.act_buckets_for("act", 3).unwrap(),
            vec![("act".into(), 1), ("act_b4".into(), 4)]
        );
        assert!(meta.act_buckets_for("act", 17).is_err());
        // ddpg prefix has no artifacts in this synthetic meta
        assert!(meta.act_artifact_for("act_ddpg", 1).is_err());
        // shard-capacity ceiling: the largest emitted (and present) batch
        assert_eq!(meta.max_act_rows("act"), 16);
        assert_eq!(meta.max_act_rows("act_ddpg"), 0);
    }

    #[test]
    fn missing_preset_errors_helpfully() {
        let err = PresetMeta::load("artifacts", "nonexistent").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn unknown_artifact_name_errors() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let meta = PresetMeta::load("artifacts", "pendulum").unwrap();
        assert!(meta.artifact("bogus").is_err());
        assert!(!meta.has_artifact("bogus"));
    }
}
