//! Property tests over coordinator invariants (routing, batching, state)
//! using the in-repo mini property harness (`util::prop`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use walle::algo::normalizer::NormSnapshot;
use walle::algo::rollout::{ChunkEnd, ExperienceChunk};
use walle::config::{DdpgCfg, PpoCfg};
use walle::coordinator::policy_store::PolicyStore;
use walle::coordinator::queue::Channel;
use walle::coordinator::sampler::{run_ppo_sampler, SamplerCfg};
use walle::env::vec_env::VecEnv;
use walle::runtime::native_backend::NativeFactory;
use walle::runtime::BackendFactory;
use walle::util::prop::{check, Gen, Pair, UsizeIn};
use walle::util::rng::Pcg64;

/// Queue invariant: per-producer FIFO order survives arbitrary
/// producer/consumer interleavings (MPMC queues may interleave across
/// producers but must never reorder one producer's items).
#[test]
fn queue_preserves_per_producer_fifo() {
    check(11, 8, &Pair(UsizeIn(1, 4), UsizeIn(1, 8)), |&(producers, cap)| {
        let ch = Arc::new(Channel::<(usize, usize)>::new(cap));
        let per = 200;
        std::thread::scope(|s| {
            for p in 0..producers {
                let ch = ch.clone();
                s.spawn(move || {
                    for i in 0..per {
                        ch.push((p, i)).unwrap();
                    }
                });
            }
            let ch2 = ch.clone();
            let consumer = s.spawn(move || {
                let mut last = vec![-1isize; producers];
                let mut ok = true;
                for _ in 0..producers * per {
                    let (p, i) = ch2.pop().unwrap();
                    ok &= (i as isize) > last[p];
                    last[p] = i as isize;
                }
                ok
            });
            consumer.join().unwrap()
        })
    });
}

/// Conservation: items pushed == items popped once drained, for random
/// capacities and counts.
#[test]
fn queue_conserves_items() {
    check(13, 30, &Pair(UsizeIn(1, 16), UsizeIn(0, 500)), |&(cap, n)| {
        let ch = Arc::new(Channel::<usize>::new(cap));
        let ch2 = ch.clone();
        let h = std::thread::spawn(move || {
            for i in 0..n {
                ch2.push(i).unwrap();
            }
            ch2.close();
        });
        let mut got = 0usize;
        while ch.pop().is_ok() {
            got += 1;
        }
        h.join().unwrap();
        got == n && ch.stats.pushed() == n as u64 && ch.stats.popped() == n as u64
    });
}

/// Sampler invariant: for any chunk size, every produced chunk has
/// consistent row counts across all parallel arrays, length within the
/// configured bound, and carries obs statistics.
#[test]
fn sampler_chunks_always_well_formed() {
    check(17, 5, &UsizeIn(7, 300), |&chunk_steps| {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::<ExperienceChunk>::new(16));
        let stop = Arc::new(AtomicBool::new(false));
        let f = NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default());
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let store2 = store.clone();
        let queue2 = queue.clone();
        let stop2 = stop.clone();
        let h = std::thread::spawn(move || {
            let f = NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default());
            run_ppo_sampler(
                SamplerCfg {
                    id: 3,
                    seed: chunk_steps as u64,
                    chunk_steps,
                    sync_budget: None,
                    reward_scale: 1.0,
                },
                VecEnv::from_registry("pendulum", 1, chunk_steps as u64, 4).unwrap(),
                f.make_actor_batched(1).unwrap(),
                &store2,
                &queue2,
                &stop2,
            )
        });

        let mut ok = true;
        let mut total = 0usize;
        while total < 400 {
            let c = queue.pop().unwrap();
            total += c.len();
            ok &= !c.is_empty();
            ok &= c.len() <= chunk_steps;
            ok &= c.obs.len() == c.len() * 3;
            ok &= c.act.len() == c.len();
            ok &= c.logp.len() == c.len() && c.value.len() == c.len();
            ok &= c.sampler_id == 3;
            ok &= c.obs_stats.as_ref().map(|s| s.count() as usize == c.len()) == Some(true);
            // pendulum never terminates on its own
            ok &= c.end != ChunkEnd::Terminal;
            ok &= c.episode_returns.len() == c.episode_lengths.len();
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let _ = h.join();
        ok
    });
}

/// Policy store invariant: versions observed by a reader are monotonic
/// and each snapshot's content matches its version, under arbitrary
/// publish bursts.
#[test]
fn policy_store_versions_monotonic_under_bursts() {
    check(19, 20, &UsizeIn(1, 50), |&bursts| {
        let store = Arc::new(PolicyStore::new());
        let s2 = store.clone();
        let writer = std::thread::spawn(move || {
            let mut rng = Pcg64::new(bursts as u64);
            for v in 0..bursts {
                s2.publish(vec![v as f32], NormSnapshot::identity(1));
                if rng.next_f32() < 0.3 {
                    std::thread::yield_now();
                }
            }
        });
        let mut last = 0u64;
        let mut ok = true;
        for _ in 0..bursts * 2 {
            if let Some(s) = store.latest() {
                ok &= s.version >= last;
                ok &= s.params[0] == (s.version - 1) as f32;
                last = s.version;
            }
        }
        writer.join().unwrap();
        ok && store.version() == bursts as u64
    });
}

/// Replay-through-chunk invariant: the DDPG chunk layout (len+1 obs rows)
/// reconstructs transitions whose next_obs equals the following row.
#[test]
fn ddpg_chunk_transition_reconstruction() {
    check(23, 40, &UsizeIn(1, 60), |&len| {
        // synthesize a chunk the way the DDPG sampler does
        let obs_dim = 2;
        let mut obs = Vec::new();
        for i in 0..=len {
            obs.extend_from_slice(&[i as f32, -(i as f32)]);
        }
        let c = ExperienceChunk {
            sampler_id: 0,
            env_slot: 0,
            policy_version: 1,
            obs,
            act: vec![0.0; len],
            rew: (0..len).map(|i| i as f32).collect(),
            logp: vec![0.0; len],
            value: vec![0.0; len],
            end: ChunkEnd::Truncated,
            bootstrap_value: 0.0,
            episode_returns: vec![],
            episode_lengths: vec![],
            obs_stats: None,
            busy_secs: 0.0,
        };
        // reconstruct like DdpgLearner::absorb_chunk
        (0..len).all(|i| {
            let next = &c.obs[(i + 1) * obs_dim..(i + 2) * obs_dim];
            next[0] == (i + 1) as f32 && next[1] == -((i + 1) as f32)
        })
    });
}
