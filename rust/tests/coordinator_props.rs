//! Property tests over coordinator invariants (routing, batching, state)
//! using the in-repo mini property harness (`util::prop`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use walle::algo::normalizer::NormSnapshot;
use walle::algo::rollout::{ChunkEnd, ExperienceChunk};
use walle::config::{DdpgCfg, PpoCfg, ReplayStrategy};
use walle::coordinator::policy_store::PolicyStore;
use walle::coordinator::queue::Channel;
use walle::coordinator::sampler::{run_ppo_sampler, SamplerCfg};
use walle::replay::shard::{ReplayRng, ShardSample, ShardedReplay};
use walle::env::vec_env::VecEnv;
use walle::runtime::native_backend::NativeFactory;
use walle::runtime::BackendFactory;
use walle::util::prop::{check, Gen, Pair, UsizeIn};
use walle::util::rng::Pcg64;

/// Queue invariant: per-producer FIFO order survives arbitrary
/// producer/consumer interleavings (MPMC queues may interleave across
/// producers but must never reorder one producer's items).
#[test]
fn queue_preserves_per_producer_fifo() {
    check(11, 8, &Pair(UsizeIn(1, 4), UsizeIn(1, 8)), |&(producers, cap)| {
        let ch = Arc::new(Channel::<(usize, usize)>::new(cap));
        let per = 200;
        std::thread::scope(|s| {
            for p in 0..producers {
                let ch = ch.clone();
                s.spawn(move || {
                    for i in 0..per {
                        ch.push((p, i)).unwrap();
                    }
                });
            }
            let ch2 = ch.clone();
            let consumer = s.spawn(move || {
                let mut last = vec![-1isize; producers];
                let mut ok = true;
                for _ in 0..producers * per {
                    let (p, i) = ch2.pop().unwrap();
                    ok &= (i as isize) > last[p];
                    last[p] = i as isize;
                }
                ok
            });
            consumer.join().unwrap()
        })
    });
}

/// Conservation: items pushed == items popped once drained, for random
/// capacities and counts.
#[test]
fn queue_conserves_items() {
    check(13, 30, &Pair(UsizeIn(1, 16), UsizeIn(0, 500)), |&(cap, n)| {
        let ch = Arc::new(Channel::<usize>::new(cap));
        let ch2 = ch.clone();
        let h = std::thread::spawn(move || {
            for i in 0..n {
                ch2.push(i).unwrap();
            }
            ch2.close();
        });
        let mut got = 0usize;
        while ch.pop().is_ok() {
            got += 1;
        }
        h.join().unwrap();
        got == n && ch.stats.pushed() == n as u64 && ch.stats.popped() == n as u64
    });
}

/// Sampler invariant: for any chunk size, every produced chunk has
/// consistent row counts across all parallel arrays, length within the
/// configured bound, and carries obs statistics.
#[test]
fn sampler_chunks_always_well_formed() {
    check(17, 5, &UsizeIn(7, 300), |&chunk_steps| {
        let store = Arc::new(PolicyStore::new());
        let queue = Arc::new(Channel::<ExperienceChunk>::new(16));
        let stop = Arc::new(AtomicBool::new(false));
        let f = NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default());
        store.publish(f.init_ppo_params(0), NormSnapshot::identity(3));

        let store2 = store.clone();
        let queue2 = queue.clone();
        let stop2 = stop.clone();
        let h = std::thread::spawn(move || {
            let f = NativeFactory::new(3, 1, &[8, 8], PpoCfg::default(), DdpgCfg::default());
            run_ppo_sampler(
                SamplerCfg {
                    id: 3,
                    seed: chunk_steps as u64,
                    chunk_steps,
                    sync_budget: None,
                    reward_scale: 1.0,
                },
                VecEnv::from_registry("pendulum", 1, chunk_steps as u64, 4).unwrap(),
                f.make_actor_batched(1).unwrap(),
                &store2,
                &queue2,
                &stop2,
            )
        });

        let mut ok = true;
        let mut total = 0usize;
        while total < 400 {
            let c = queue.pop().unwrap();
            total += c.len();
            ok &= !c.is_empty();
            ok &= c.len() <= chunk_steps;
            ok &= c.obs.len() == c.len() * 3;
            ok &= c.act.len() == c.len();
            ok &= c.logp.len() == c.len() && c.value.len() == c.len();
            ok &= c.sampler_id == 3;
            ok &= c.obs_stats.as_ref().map(|s| s.count() as usize == c.len()) == Some(true);
            // pendulum never terminates on its own
            ok &= c.end != ChunkEnd::Terminal;
            ok &= c.episode_returns.len() == c.episode_lengths.len();
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
        let _ = h.join();
        ok
    });
}

/// Policy store invariant: versions observed by a reader are monotonic
/// and each snapshot's content matches its version, under arbitrary
/// publish bursts.
#[test]
fn policy_store_versions_monotonic_under_bursts() {
    check(19, 20, &UsizeIn(1, 50), |&bursts| {
        let store = Arc::new(PolicyStore::new());
        let s2 = store.clone();
        let writer = std::thread::spawn(move || {
            let mut rng = Pcg64::new(bursts as u64);
            for v in 0..bursts {
                s2.publish(vec![v as f32], NormSnapshot::identity(1));
                if rng.next_f32() < 0.3 {
                    std::thread::yield_now();
                }
            }
        });
        let mut last = 0u64;
        let mut ok = true;
        for _ in 0..bursts * 2 {
            if let Some(s) = store.latest() {
                ok &= s.version >= last;
                ok &= s.params[0] == (s.version - 1) as f32;
                last = s.version;
            }
        }
        writer.join().unwrap();
        ok && store.version() == bursts as u64
    });
}

/// Tentpole invariant (PR 8): the minibatch draw sequence is a pure
/// function of (seed, draw counter, window contents) — the shard count
/// never leaks in. Stronger than set-equality: the rows come back in the
/// same ORDER, which is what makes downstream gradients bitwise stable.
#[test]
fn replay_draws_are_shard_count_invariant() {
    check(29, 25, &Pair(UsizeIn(1, 150), UsizeIn(1, 32)), |&(extra, batch)| {
        let cap = 64usize;
        let n = cap / 2 + extra; // below, at, and past the wrap point
        let seed = (extra * 31 + batch) as u64;
        let mut reference: Option<Vec<Vec<u64>>> = None;
        let mut ok = true;
        for shards in [1usize, 2, 4] {
            let buf = ShardedReplay::new(cap, 2, 1, shards, ReplayStrategy::Uniform);
            for i in 0..n {
                let f = i as f32;
                buf.push(&[f, -f], &[f * 0.5], f, &[f + 1.0, -(f + 1.0)], i % 5 == 0);
            }
            let mut rng = ReplayRng::new(seed);
            let mut s = ShardSample::default();
            let draws: Vec<Vec<u64>> = (0..6)
                .map(|_| {
                    buf.sample_into(batch, &mut rng, &mut s);
                    for row in 0..batch {
                        // each row's lanes belong to the tagged index
                        ok &= s.obs[row * 2] == s.indices[row] as f32;
                        ok &= s.rew[row] == s.indices[row] as f32;
                        ok &= s.weights[row] == 1.0;
                    }
                    s.indices.clone()
                })
                .collect();
            match &reference {
                None => reference = Some(draws),
                Some(want) => ok &= want == &draws,
            }
        }
        ok
    });
}

/// Concurrent striped inserts never lose or duplicate a transition:
/// whatever the lane interleaving, the window holds exactly the newest
/// `min(total, C)` global indices and every sampled row's lanes stay
/// mutually consistent (obs/act/rew/next_obs all from the same insert).
#[test]
fn replay_concurrent_inserts_conserve_the_window() {
    check(31, 12, &Pair(UsizeIn(1, 4), UsizeIn(1, 80)), |&(lanes, per_lane)| {
        let buf = ShardedReplay::new(96, 2, 1, lanes, ReplayStrategy::Uniform);
        std::thread::scope(|sc| {
            for lane in 0..lanes {
                let buf = &buf;
                sc.spawn(move || {
                    for i in 0..per_lane {
                        let id = (lane * 1000 + i) as f32;
                        buf.push(&[id, -id], &[id], id, &[id + 1.0, -(id + 1.0)], false);
                    }
                });
            }
        });
        let total = lanes * per_lane;
        let mut ok = buf.total_inserted() == total as u64;
        ok &= buf.len() == total.min(96);
        let mut rng = ReplayRng::new(3);
        let mut s = ShardSample::default();
        buf.sample_into(64, &mut rng, &mut s);
        for row in 0..64 {
            let id = s.obs[row * 2];
            ok &= s.act[row] == id && s.rew[row] == id;
            ok &= s.next_obs[row * 2] == id + 1.0 && s.obs[row * 2 + 1] == -id;
            // drawn ids decode to a (lane, i) that was actually pushed
            let (lane, i) = ((id as usize) / 1000, (id as usize) % 1000);
            ok &= lane < lanes && i < per_lane;
        }
        ok
    });
}

/// Prioritized replay: probabilities are a normalized distribution, an
/// extreme priority spread never starves the cold transitions (the EPS
/// floor keeps every stored row reachable), the dominant row dominates
/// the draws, and IS weights are finite, positive, and max-normalized.
#[test]
fn prioritized_replay_normalizes_and_never_starves() {
    check(37, 20, &Pair(UsizeIn(2, 5), UsizeIn(0, 60)), |&(shards, hot)| {
        let cap = 64usize;
        let hot = (hot as u64).min(cap as u64 - 1);
        let buf = ShardedReplay::new(cap, 2, 1, shards, ReplayStrategy::Prioritized);
        for i in 0..cap {
            let f = i as f32;
            buf.push(&[f, -f], &[f], f, &[f + 1.0, f], false);
        }
        let idx: Vec<u64> = (0..cap as u64).collect();
        let mut td = vec![0.0f32; cap];
        td[hot as usize] = 1e6;
        buf.update_priorities(&idx, &td);
        let mut ok = true;
        let mass: f64 = (0..cap as u64)
            .map(|g| {
                let p = buf.sampling_prob(g).unwrap();
                ok &= p > 0.0; // reachable: no starvation
                p
            })
            .sum();
        ok &= (mass - 1.0).abs() < 1e-9;
        let mut rng = ReplayRng::new(hot + 17);
        let mut s = ShardSample::default();
        buf.sample_into(32, &mut rng, &mut s);
        ok &= s.weights.iter().all(|w| w.is_finite() && *w > 0.0 && *w <= 1.0);
        ok &= s.weights.iter().any(|w| (*w - 1.0).abs() < 1e-6);
        ok &= s.indices.iter().filter(|&&g| g == hot).count() >= 16;
        ok
    });
}

/// Replay-through-chunk invariant: the DDPG chunk layout (len+1 obs rows)
/// reconstructs transitions whose next_obs equals the following row.
#[test]
fn ddpg_chunk_transition_reconstruction() {
    check(23, 40, &UsizeIn(1, 60), |&len| {
        // synthesize a chunk the way the DDPG sampler does
        let obs_dim = 2;
        let mut obs = Vec::new();
        for i in 0..=len {
            obs.extend_from_slice(&[i as f32, -(i as f32)]);
        }
        let c = ExperienceChunk {
            sampler_id: 0,
            env_slot: 0,
            policy_version: 1,
            obs,
            act: vec![0.0; len],
            rew: (0..len).map(|i| i as f32).collect(),
            logp: vec![0.0; len],
            value: vec![0.0; len],
            end: ChunkEnd::Truncated,
            bootstrap_value: 0.0,
            episode_returns: vec![],
            episode_lengths: vec![],
            obs_stats: None,
            busy_secs: 0.0,
        };
        // reconstruct like DdpgLearner::absorb_chunk
        (0..len).all(|i| {
            let next = &c.obs[(i + 1) * obs_dim..(i + 2) * obs_dim];
            next[0] == (i + 1) as f32 && next[1] == -((i + 1) as f32)
        })
    });
}
