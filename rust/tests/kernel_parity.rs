//! Kernel-layer parity and int8 end-to-end tests.
//!
//! The exact-mode contract (`--kernels exact`, the default) is BITWISE:
//! the dispatched SIMD arm must produce exactly the same f32 bits as
//! the portable scalar reference for every shape — including ragged
//! ones (1-row, 1-col, non-multiple-of-lane) that exercise the vector
//! remainder paths. On a machine without AVX2/NEON the dispatched arm
//! IS scalar and the parity tests pass trivially; CI runs a
//! `-C target-cpu=native` leg so the SIMD arms are exercised where the
//! hardware allows, and a `WALLE_KERNELS=scalar` leg pinning the
//! portable arm.
//!
//! Fast mode (`--kernels fast`) trades the bitwise guarantee for FMA
//! register tiling; its documented tolerance (relative ~1e-6 drift,
//! asserted here at 1e-4 on normal-scale inputs) is checked too. The
//! int8 path has no f32-parity claim at all — its contract is
//! scalar-vs-SIMD bitwise agreement plus a NaN-free end-to-end run.
//!
//! Every parity test dispatches through the `*_via` entry points, so no
//! process-global kernel state is mutated and the tests are safe under
//! the default parallel test runner.

use walle::nn::kernels::{self, KernelMode, Lanes};
use walle::util::rng::Pcg64;

/// Ragged + aligned dims: 1, below/at/above the 8-float AVX2 lane, and
/// above the 16-column register tile of the fast GEMM.
const DIMS: [usize; 7] = [1, 3, 7, 8, 9, 17, 33];

fn rand_vec(rng: &mut Pcg64, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v);
    v
}

/// ~25% exact zeros so the scalar arm's `a == 0.0` row-skip — which the
/// exact SIMD arms must replicate — actually fires.
fn sparse_vec(rng: &mut Pcg64, len: usize) -> Vec<f32> {
    let mut v = rand_vec(rng, len);
    for x in v.iter_mut() {
        if rng.uniform(0.0, 1.0) < 0.25 {
            *x = 0.0;
        }
    }
    v
}

fn assert_bitwise(s: &[f32], v: &[f32], what: &str) {
    for (i, (a, b)) in s.iter().zip(v).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{what}: element {i} diverged ({a} vs {b})"
        );
    }
}

fn assert_close(s: &[f32], v: &[f32], tol: f32, what: &str) {
    for (i, (a, b)) in s.iter().zip(v).enumerate() {
        let denom = a.abs().max(b.abs()).max(1.0);
        assert!(
            (a - b).abs() / denom <= tol,
            "{what}: element {i} off by more than {tol} ({a} vs {b})"
        );
    }
}

#[test]
fn exact_mode_gemm_is_bitwise_identical_across_ragged_shapes() {
    let arm = kernels::active();
    let mut rng = Pcg64::new(42);
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                let a = sparse_vec(&mut rng, m * k);
                let b = rand_vec(&mut rng, k * n);
                let mut s = vec![0.0f32; m * n];
                let mut v = vec![0.0f32; m * n];
                kernels::matmul_via(Lanes::Scalar, KernelMode::Exact, &a, &b, &mut s, m, k, n);
                kernels::matmul_via(arm, KernelMode::Exact, &a, &b, &mut v, m, k, n);
                assert_bitwise(&s, &v, &format!("matmul {m}x{k}x{n}"));

                let at = sparse_vec(&mut rng, k * m);
                s.iter_mut().for_each(|x| *x = 0.0);
                v.iter_mut().for_each(|x| *x = 0.0);
                kernels::matmul_tn_via(Lanes::Scalar, KernelMode::Exact, &at, &b, &mut s, m, k, n);
                kernels::matmul_tn_via(arm, KernelMode::Exact, &at, &b, &mut v, m, k, n);
                assert_bitwise(&s, &v, &format!("matmul_tn {m}x{k}x{n}"));

                let bt = rand_vec(&mut rng, n * k);
                s.iter_mut().for_each(|x| *x = 0.0);
                v.iter_mut().for_each(|x| *x = 0.0);
                kernels::matmul_nt_via(Lanes::Scalar, KernelMode::Exact, &a, &bt, &mut s, m, k, n);
                kernels::matmul_nt_via(arm, KernelMode::Exact, &a, &bt, &mut v, m, k, n);
                assert_bitwise(&s, &v, &format!("matmul_nt {m}x{k}x{n}"));
            }
        }
    }
}

#[test]
fn exact_mode_gemm_accumulates_into_nonzero_out() {
    // the += contract: parity must hold when callers accumulate into a
    // buffer that already carries values (mlp_backward does this)
    let arm = kernels::active();
    let mut rng = Pcg64::new(5);
    let (m, k, n) = (9, 17, 13);
    let a = rand_vec(&mut rng, m * k);
    let b = rand_vec(&mut rng, k * n);
    let seed = rand_vec(&mut rng, m * n);
    let mut s = seed.clone();
    let mut v = seed;
    kernels::matmul_via(Lanes::Scalar, KernelMode::Exact, &a, &b, &mut s, m, k, n);
    kernels::matmul_via(arm, KernelMode::Exact, &a, &b, &mut v, m, k, n);
    assert_bitwise(&s, &v, "accumulating matmul");
}

#[test]
fn elementwise_kernels_match_bitwise() {
    let arm = kernels::active();
    let mut rng = Pcg64::new(7);
    for &rows in &DIMS {
        for &cols in &DIMS {
            let x0 = rand_vec(&mut rng, rows * cols);
            let bias = rand_vec(&mut rng, cols);
            let mut s = x0.clone();
            let mut v = x0;
            kernels::add_bias_via(Lanes::Scalar, &mut s, &bias, rows, cols);
            kernels::add_bias_via(arm, &mut v, &bias, rows, cols);
            assert_bitwise(&s, &v, &format!("add_bias {rows}x{cols}"));
            kernels::relu_via(Lanes::Scalar, &mut s);
            kernels::relu_via(arm, &mut v);
            assert_bitwise(&s, &v, &format!("relu {rows}x{cols}"));
        }
    }
}

#[test]
fn fast_mode_stays_within_documented_tolerance() {
    let arm = kernels::active();
    let mut rng = Pcg64::new(9);
    for &(m, k, n) in &[(1usize, 17usize, 64usize), (9, 33, 7), (16, 64, 64), (33, 128, 6)] {
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut s = vec![0.0f32; m * n];
        let mut v = vec![0.0f32; m * n];
        kernels::matmul_via(Lanes::Scalar, KernelMode::Exact, &a, &b, &mut s, m, k, n);
        kernels::matmul_via(arm, KernelMode::Fast, &a, &b, &mut v, m, k, n);
        assert_close(&s, &v, 1e-4, &format!("fast matmul {m}x{k}x{n}"));

        let bt = rand_vec(&mut rng, n * k);
        s.iter_mut().for_each(|x| *x = 0.0);
        v.iter_mut().for_each(|x| *x = 0.0);
        kernels::matmul_nt_via(Lanes::Scalar, KernelMode::Exact, &a, &bt, &mut s, m, k, n);
        kernels::matmul_nt_via(arm, KernelMode::Fast, &a, &bt, &mut v, m, k, n);
        assert_close(&s, &v, 1e-4, &format!("fast matmul_nt {m}x{k}x{n}"));
    }
}

#[test]
fn int8_gemm_simd_matches_scalar_bitwise() {
    // the int8 arms share one dequant expression (mul then add, in j
    // order), so scalar-vs-SIMD agreement is exact — no tolerance
    let arm = kernels::active();
    let mut rng = Pcg64::new(11);
    for &m in &[1usize, 3, 8, 17] {
        for &k in &[1usize, 7, 16, 33] {
            for &n in &[1usize, 5, 16, 23] {
                let a = rand_vec(&mut rng, m * k);
                let b = rand_vec(&mut rng, k * n);
                let bias = rand_vec(&mut rng, n);
                let mut aq = vec![0i8; m * k];
                let mut ascale = vec![0.0f32; m];
                let mut bq = vec![0i8; k * n];
                let mut bscale = vec![0.0f32; n];
                kernels::quantize_rows(&a, m, k, &mut aq, &mut ascale);
                kernels::quantize_cols(&b, k, n, &mut bq, &mut bscale);
                let mut s = vec![0.0f32; m * n];
                let mut v = vec![0.0f32; m * n];
                kernels::matmul_q8_via(
                    Lanes::Scalar,
                    &aq,
                    &ascale,
                    &bq,
                    &bscale,
                    &bias,
                    &mut s,
                    m,
                    k,
                    n,
                );
                kernels::matmul_q8_via(
                    arm, &aq, &ascale, &bq, &bscale, &bias, &mut v, m, k, n,
                );
                assert_bitwise(&s, &v, &format!("matmul_q8 {m}x{k}x{n}"));
            }
        }
    }
}

// ------------------------------------------------------- int8 end-to-end

mod int8_e2e {
    use walle::config::{Backend, InferPrecision, InferWait, InferenceMode, TrainConfig};
    use walle::coordinator::metrics::MetricsLog;
    use walle::coordinator::orchestrator;
    use walle::runtime::make_factory;
    use walle::session::Session;

    fn int8_cfg() -> TrainConfig {
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.backend = Backend::Native;
        cfg.samplers = 3;
        cfg.samples_per_iter = 600;
        cfg.iterations = 3;
        cfg.chunk_steps = 100;
        cfg.hidden = vec![16, 16];
        cfg.ppo.epochs = 2;
        cfg.ppo.minibatch = 128;
        cfg.inference_mode = InferenceMode::Shared;
        cfg.infer_wait = InferWait::Fixed(500);
        cfg.infer_precision = InferPrecision::Int8;
        cfg
    }

    /// The quantized actor path drives the whole fleet: every sampled
    /// step goes through int8 forwards while the learner stays f32. The
    /// run must complete with finite returns and parameters, and
    /// evaluation of the trained (f32) checkpoint must be finite too.
    #[test]
    fn int8_shared_inference_ppo_trains_and_evaluates_without_nans() {
        let cfg = int8_cfg();
        let f = make_factory(&cfg).unwrap();
        let mut log = MetricsLog::quiet();
        let r = orchestrator::run(&cfg, f.as_ref(), &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        for m in &r.metrics {
            assert!(m.samples >= 600);
            assert!(
                m.mean_return.is_finite(),
                "mean return went non-finite: {}",
                m.mean_return
            );
        }
        assert!(r.final_params.iter().all(|p| p.is_finite()));
        let rep = r.infer.expect("shared mode must produce a report");
        assert!(rep.forwards > 0, "server never dispatched");

        let session = Session::from_config(int8_cfg()).unwrap();
        let ev = session
            .evaluate_with_norm(&r.final_params, &r.final_norm, 2)
            .unwrap();
        assert!(ev.mean_return.is_finite());
    }

    /// Same guarantee for the deterministic-actor algorithms (the
    /// DDPG/TD3 quantizer quantizes the actor head only).
    #[test]
    fn int8_shared_inference_ddpg_trains_without_nans() {
        let mut cfg = int8_cfg();
        cfg.algo = walle::config::Algo::Ddpg;
        cfg.samples_per_iter = 300;
        cfg.ddpg.warmup_steps = 100;
        cfg.ddpg.batch = 32;
        cfg.ddpg.updates_per_iter = 10;
        let f = make_factory(&cfg).unwrap();
        let mut log = MetricsLog::quiet();
        let r = orchestrator::run(&cfg, f.as_ref(), &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        assert!(r.final_params.iter().all(|p| p.is_finite()));
        assert!(r.infer.unwrap().forwards > 0);
    }

    /// `--kernels fast` is a live configuration end to end, not just a
    /// microkernel flag: a short f32 training run under it completes
    /// with finite results. (Bitwise determinism is only promised in
    /// exact mode; the PR 4 determinism suite runs there.)
    #[test]
    fn fast_kernels_train_run_completes() {
        let mut cfg = int8_cfg();
        cfg.infer_precision = InferPrecision::F32;
        cfg.kernels = walle::config::KernelsCfg::Fast;
        let f = make_factory(&cfg).unwrap();
        let mut log = MetricsLog::quiet();
        let r = orchestrator::run(&cfg, f.as_ref(), &mut log).unwrap();
        assert_eq!(r.metrics.len(), 3);
        assert!(r.final_params.iter().all(|p| p.is_finite()));
        // restore the process-global default for any test scheduled after
        walle::nn::kernels::set_mode(walle::nn::kernels::KernelMode::Exact);
    }

    /// The session builder spells the same knobs as the CLI flags.
    #[test]
    fn builder_threads_precision_and_kernels_into_config() {
        let s = Session::builder()
            .env("pendulum")
            .backend(Backend::Native)
            .infer(walle::session::Infer::Shared {
                shards: walle::config::InferShards::Auto,
            })
            .infer_precision(InferPrecision::Int8)
            .kernels(walle::config::KernelsCfg::Fast)
            .build()
            .unwrap();
        assert_eq!(s.config().infer_precision, InferPrecision::Int8);
        assert_eq!(s.config().kernels, walle::config::KernelsCfg::Fast);

        // int8 without shared inference must fail at build time
        let err = Session::builder()
            .env("pendulum")
            .backend(Backend::Native)
            .infer_precision(InferPrecision::Int8)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("shared"), "unexpected error: {err}");
    }
}
