//! Chaos suite: deterministic fault injection against the full
//! coordinator (the tentpole acceptance runs). Every test drives the
//! REAL topology — N sampler workers, the sharded inference pool, the
//! learner — with scripted kills from `--fault-inject`, and checks the
//! self-healing contract:
//!
//! * the run completes and the restart/fault counters match the plan;
//! * in sync mode the run's output is BITWISE identical to a fault-free
//!   run (supervised respawn restores the worker's RNG lanes and replays
//!   already-delivered chunks without re-pushing them, and the learner
//!   folds chunks in canonical order, so arrival timing cannot leak in);
//! * kill-then-resume from the latest durable checkpoint reproduces the
//!   uninterrupted run bitwise.
//!
//! CI runs this file under a hard `timeout` (see the chaos job): a
//! supervision bug that deadlocks shows up as a timeout kill, not a
//! silently hung pipeline.

use walle::config::{Algo, InferShards, InferWait, InferenceMode, TrainConfig};
use walle::coordinator::metrics::MetricsLog;
use walle::coordinator::orchestrator;
use walle::runtime::make_factory;

/// The acceptance fleet: sync barrier mode, N=4 workers x M=2 envs,
/// S=2 inference shards, 640 samples/iteration in 40-step chunks.
fn acceptance_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::preset("pendulum");
    cfg.backend = walle::config::Backend::Native;
    cfg.samplers = 4;
    cfg.envs_per_sampler = 2;
    cfg.async_mode = false;
    cfg.inference_mode = InferenceMode::Shared;
    cfg.infer_shards = InferShards::Fixed(2);
    cfg.infer_wait = InferWait::Fixed(500);
    cfg.samples_per_iter = 640;
    cfg.chunk_steps = 40;
    cfg.iterations = 3;
    cfg.hidden = vec![16, 16];
    cfg.ppo.epochs = 2;
    cfg.ppo.minibatch = 128;
    cfg
}

fn run_cfg(cfg: &TrainConfig) -> orchestrator::RunResult {
    let factory = make_factory(cfg).unwrap();
    let mut log = MetricsLog::quiet();
    orchestrator::run(cfg, factory.as_ref(), &mut log).unwrap()
}

/// Tentpole acceptance: kill one sampler worker AND one inference shard
/// mid-run per a scripted plan. The supervisor respawns both, the run
/// completes, the counters match the plan exactly, and the final policy
/// parameters are bitwise identical to a fault-free run — the strongest
/// externally observable witness that every per-env chunk stream the
/// learner consumed was bitwise identical.
#[test]
fn scripted_worker_and_shard_kills_heal_bitwise() {
    let clean = acceptance_cfg();
    let reference = run_cfg(&clean);
    assert_eq!(reference.metrics.len(), 3);
    assert_eq!(reference.restarts, 0);

    let mut faulted_cfg = acceptance_cfg();
    // worker 1 dies at lifetime tick 100 (mid first iteration: 80 ticks
    // per version); shard 0 dies at its 60th dispatch
    faulted_cfg.fault_inject = "worker:1@tick:100,shard:0@dispatch:60".into();
    let faulted = run_cfg(&faulted_cfg);

    assert_eq!(faulted.metrics.len(), 3, "faulted run must complete");
    assert_eq!(faulted.faults_injected, 2, "both scripted cells must fire");
    assert_eq!(faulted.restarts, 2, "one respawn per kill");
    assert_eq!(
        faulted.final_params, reference.final_params,
        "self-healed run must be bitwise identical to the fault-free run"
    );

    // satellite 6: the merged inference report carries the fleet-health
    // counters through render + json
    let rep = faulted.infer.expect("shared run must carry a report");
    assert_eq!(rep.restarts, 2);
    assert_eq!(rep.faults_injected, 2);
    let rendered = rep.render();
    assert!(rendered.contains("2 restarts"), "render: {rendered}");
    assert!(rendered.contains("2 scripted faults fired"), "render: {rendered}");
    let json = rep.to_json().to_string();
    assert!(json.contains("\"restarts\":2"), "json: {json}");
    assert!(json.contains("\"faults_injected\":2"), "json: {json}");
}

/// Kill-then-resume acceptance: checkpoint every iteration, then start a
/// fresh fleet from the second checkpoint (as if the process had been
/// killed after iteration 2) and replay the remainder. The resumed run
/// must land on the same final parameters bitwise as the uninterrupted
/// reference — learner state, policy-store version, and every worker's
/// RNG/env cursors all survived the round trip.
#[test]
fn kill_then_resume_reproduces_reference_bitwise() {
    let dir = std::env::temp_dir().join("walle_chaos_resume");
    let _ = std::fs::remove_dir_all(&dir);

    let mut cfg = acceptance_cfg();
    cfg.checkpoint_every = 1;
    cfg.checkpoint_dir = dir.to_str().unwrap().to_string();
    let full = run_cfg(&cfg);
    assert_eq!(full.checkpoint_write_us.len(), 3, "one checkpoint per iteration");
    let rep = full.infer.expect("shared run must carry a report");
    assert_eq!(
        rep.checkpoint_write_us.count(),
        3,
        "checkpoint write timings must ride the merged report"
    );

    // "kill" after iteration 2: resume from ckpt-000002 by removing the
    // last snapshot so load_latest picks the second one
    std::fs::remove_file(dir.join("ckpt-000003.bin")).unwrap();
    let mut resume_cfg = acceptance_cfg();
    resume_cfg.resume = dir.to_str().unwrap().to_string();
    let resumed = run_cfg(&resume_cfg);

    assert_eq!(resumed.metrics.len(), 1, "only the final iteration reruns");
    assert_eq!(
        resumed.final_params, full.final_params,
        "resume must reproduce the uninterrupted run bitwise"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Faults during a checkpointed run: the healed run's checkpoints are as
/// good as a healthy run's — resuming from one reproduces the healthy
/// reference bitwise even though the checkpoint was written by a fleet
/// that had already respawned a worker.
#[test]
fn resume_from_checkpoint_written_after_a_fault_is_clean() {
    let dir = std::env::temp_dir().join("walle_chaos_faulted_ckpt");
    let _ = std::fs::remove_dir_all(&dir);

    let clean = acceptance_cfg();
    let reference = run_cfg(&clean);

    let mut cfg = acceptance_cfg();
    cfg.checkpoint_every = 1;
    cfg.checkpoint_dir = dir.to_str().unwrap().to_string();
    cfg.fault_inject = "worker:2@tick:100".into();
    let faulted = run_cfg(&cfg);
    assert_eq!(faulted.restarts, 1);
    assert_eq!(faulted.final_params, reference.final_params);

    std::fs::remove_file(dir.join("ckpt-000003.bin")).unwrap();
    let mut resume_cfg = acceptance_cfg();
    resume_cfg.resume = dir.to_str().unwrap().to_string();
    let resumed = run_cfg(&resume_cfg);
    assert_eq!(
        resumed.final_params, reference.final_params,
        "a checkpoint written after self-healing must resume bitwise clean"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Seeded random fault plans expand deterministically against the fleet
/// shape and heal like scripted ones: the run completes with exactly the
/// planned number of fired cells.
#[test]
fn random_fault_plan_heals_under_default_budget() {
    let mut cfg = acceptance_cfg();
    cfg.infer_shards = InferShards::Fixed(1);
    // one random kill somewhere in the first ~50 progress units of a
    // worker or the shard — fires well inside the run
    cfg.fault_inject = "random:seed=7,count=1,horizon=50".into();
    let r = run_cfg(&cfg);
    assert_eq!(r.metrics.len(), 3, "randomly faulted run must complete");
    assert_eq!(r.faults_injected, 1);
    assert_eq!(r.restarts, 1);
}

/// Async-mode healing: the same scripted worker kill under the
/// free-running architecture completes with the counters matching the
/// plan. (Bitwise equality is a sync-mode guarantee only — async chunk
/// interleaving is timing-dependent by design.)
#[test]
fn async_scripted_kill_heals() {
    let mut cfg = acceptance_cfg();
    cfg.async_mode = true;
    cfg.fault_inject = "worker:0@tick:150".into();
    let r = run_cfg(&cfg);
    assert_eq!(r.metrics.len(), 3);
    assert_eq!(r.faults_injected, 1);
    assert_eq!(r.restarts, 1);
    let total_steps: u64 = r.sampler_reports.iter().map(|s| s.steps).sum();
    assert!(total_steps > 0);
}

/// Budget exhaustion is a clean abort, not a hang: three kills against a
/// budget of one make the run fail loudly while every thread joins
/// (this test finishing at all IS the no-deadlock assertion; CI's hard
/// timeout backstops it).
#[test]
fn budget_exhaustion_aborts_cleanly() {
    let mut cfg = acceptance_cfg();
    cfg.max_restarts = 1;
    cfg.fault_inject = "worker:3@tick:40,worker:3@tick:80,worker:3@tick:120".into();
    let factory = make_factory(&cfg).unwrap();
    let mut log = MetricsLog::quiet();
    let r = orchestrator::run(&cfg, factory.as_ref(), &mut log);
    assert!(r.is_err(), "exhausted budget must fail the run");
}

// -------------------------------------------- off-policy determinism (PR 8)

/// The acceptance fleet re-targeted at an off-policy learner: same sync
/// topology, with warmup/batch/update counts sized so the learner is
/// sampling replayed minibatches from the first iteration on (640
/// samples/iteration against a 200-step warmup).
fn off_policy_cfg(algo: Algo) -> TrainConfig {
    let mut cfg = acceptance_cfg();
    cfg.algo = algo;
    match algo {
        Algo::Ddpg => {
            cfg.ddpg.warmup_steps = 200;
            cfg.ddpg.batch = 64;
            cfg.ddpg.updates_per_iter = 20;
        }
        Algo::Td3 => {
            cfg.td3.warmup_steps = 200;
            cfg.td3.batch = 64;
            cfg.td3.updates_per_iter = 20;
        }
        _ => panic!("off_policy_cfg drives the replay learners"),
    }
    cfg
}

/// Tentpole determinism: the parallel learner publishes BITWISE identical
/// parameters for any `--learner-threads` L, for both DDPG and TD3, end
/// to end through the full fleet — grained per-minibatch gradients
/// recombine through a fixed-order tree reduction, so the thread count
/// can only change wall-clock, never the math.
#[test]
fn off_policy_learner_threads_are_bitwise_invariant_end_to_end() {
    for algo in [Algo::Ddpg, Algo::Td3] {
        let mut reference: Option<Vec<f32>> = None;
        for threads in [1usize, 2, 4] {
            let mut cfg = off_policy_cfg(algo);
            cfg.learner_threads = threads;
            let r = run_cfg(&cfg);
            assert_eq!(r.metrics.len(), 3, "{}: L={threads}", algo.name());
            assert!(r.final_params.iter().all(|p| p.is_finite()));
            match &reference {
                None => reference = Some(r.final_params),
                Some(want) => assert_eq!(
                    want,
                    &r.final_params,
                    "{}: L={threads} must publish bitwise-identical params",
                    algo.name()
                ),
            }
        }
        // the invariance is about a learner that actually learns: with
        // updates gated off (warmup never satisfied) the run must land
        // elsewhere — the published actor is still its initialization
        let mut frozen = off_policy_cfg(algo);
        match algo {
            Algo::Ddpg => frozen.ddpg.warmup_steps = 1_000_000,
            Algo::Td3 => frozen.td3.warmup_steps = 1_000_000,
            _ => unreachable!(),
        }
        let f = run_cfg(&frozen);
        assert_ne!(
            Some(f.final_params),
            reference,
            "{}: updates never ran — the sweep compared unchanged inits",
            algo.name()
        );
    }
}

/// Sharding the replay store is a pure throughput knob: sampling is
/// defined on the global insert sequence, so S ∈ {1, 2, 4} shards draw
/// the same minibatches in the same order and the run publishes bitwise
/// identical parameters.
#[test]
fn replay_shard_count_is_bitwise_invariant_end_to_end() {
    let mut reference: Option<Vec<f32>> = None;
    for shards in [1usize, 2, 4] {
        let mut cfg = off_policy_cfg(Algo::Ddpg);
        cfg.replay_shards = shards;
        let r = run_cfg(&cfg);
        assert_eq!(r.metrics.len(), 3, "S={shards}");
        match &reference {
            None => reference = Some(r.final_params),
            Some(want) => assert_eq!(
                want,
                &r.final_params,
                "S={shards} must draw the same minibatch sequence"
            ),
        }
    }
}

/// Self-healing holds for the replay learners too: a scripted worker kill
/// mid-run respawns and the final TD3 parameters are bitwise identical to
/// a fault-free run (chunk absorption is canonically ordered, so respawn
/// timing cannot leak into the replay insert sequence).
#[test]
fn off_policy_scripted_kill_heals_bitwise() {
    let clean = off_policy_cfg(Algo::Td3);
    let reference = run_cfg(&clean);

    let mut faulted_cfg = off_policy_cfg(Algo::Td3);
    faulted_cfg.fault_inject = "worker:1@tick:100".into();
    let faulted = run_cfg(&faulted_cfg);
    assert_eq!(faulted.metrics.len(), 3);
    assert_eq!(faulted.faults_injected, 1);
    assert_eq!(faulted.restarts, 1);
    assert_eq!(
        faulted.final_params, reference.final_params,
        "healed off-policy run must match the fault-free run bitwise"
    );
}

/// Replay-contents checkpointing (PR 8 bugfix): checkpoints used to
/// persist only the replay-buffer cursor, so a resumed DDPG run sampled
/// minibatches from a zeroed buffer and silently diverged. Format v2
/// embeds the full window; kill-then-resume must now be bitwise
/// identical INCLUDING the replayed minibatches — and because the
/// serialized window is shard-count-portable and the grained gradient is
/// thread-count-invariant, resuming under a different S and L still
/// reproduces the reference.
#[test]
fn ddpg_kill_then_resume_replays_identical_minibatches() {
    let dir = std::env::temp_dir().join("walle_chaos_ddpg_resume");
    let _ = std::fs::remove_dir_all(&dir);

    let mut cfg = off_policy_cfg(Algo::Ddpg);
    cfg.replay_shards = 2;
    cfg.learner_threads = 2;
    cfg.checkpoint_every = 1;
    cfg.checkpoint_dir = dir.to_str().unwrap().to_string();
    let full = run_cfg(&cfg);
    assert_eq!(full.checkpoint_write_us.len(), 3);

    // "kill" after iteration 2: drop the last snapshot so resume replays
    // the final iteration, whose updates sample from the restored window
    std::fs::remove_file(dir.join("ckpt-000003.bin")).unwrap();
    let mut resume_cfg = off_policy_cfg(Algo::Ddpg);
    resume_cfg.replay_shards = 2;
    resume_cfg.learner_threads = 2;
    resume_cfg.resume = dir.to_str().unwrap().to_string();
    let resumed = run_cfg(&resume_cfg);
    assert_eq!(resumed.metrics.len(), 1, "only the final iteration reruns");
    assert_eq!(
        resumed.final_params, full.final_params,
        "resume must replay bitwise-identical minibatches"
    );

    // resume the same checkpoint under a different replay/learner
    // topology: the restored window re-stripes and the grains re-split,
    // but the published parameters cannot move
    let mut retopo_cfg = off_policy_cfg(Algo::Ddpg);
    retopo_cfg.replay_shards = 4;
    retopo_cfg.learner_threads = 1;
    retopo_cfg.resume = dir.to_str().unwrap().to_string();
    let retopo = run_cfg(&retopo_cfg);
    assert_eq!(
        retopo.final_params, full.final_params,
        "replay checkpoints must be shard- and thread-count portable"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
