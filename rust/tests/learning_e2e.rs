//! Learning end-to-end: the full WALL-E stack must actually *learn* —
//! pendulum PPO return improves substantially over a short native-backend
//! run (fast, artifact-free), and the N>1 configuration learns as well as
//! N=1 at equal sample budget (the paper's "parallelism does not hurt
//! average return" claim, Fig 3/4 discussion).

use walle::config::{Backend, TrainConfig};
use walle::coordinator::metrics::MetricsLog;
use walle::coordinator::orchestrator;
use walle::runtime::make_factory;
use walle::util::stats::mean_f32;

fn learn_cfg(samplers: usize, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::preset("pendulum");
    cfg.backend = Backend::Native;
    cfg.samplers = samplers;
    cfg.seed = seed;
    cfg.samples_per_iter = 4_000;
    cfg.iterations = 40;
    cfg.chunk_steps = 200;
    cfg.hidden = vec![64, 64];
    cfg.ppo.epochs = 10;
    cfg.ppo.minibatch = 256;
    cfg.ppo.lr = 1e-3;
    cfg
}

fn run_returns(cfg: &TrainConfig) -> Vec<f32> {
    let factory = make_factory(cfg).unwrap();
    let mut log = MetricsLog::quiet();
    let r = orchestrator::run(cfg, factory.as_ref(), &mut log).unwrap();
    r.metrics.iter().map(|m| m.mean_return).collect()
}

#[test]
fn ppo_improves_pendulum_return() {
    let returns = run_returns(&learn_cfg(4, 0));
    let early = mean_f32(&returns[..3]);
    // best 5-iteration window in the back half (PPO curves oscillate)
    let best_late = returns[returns.len() / 2..]
        .windows(5)
        .map(mean_f32)
        .fold(f32::NEG_INFINITY, f32::max);
    // pendulum random policy ~ -1100..-1400; a learning run reaches much
    // better than that within 40 iterations (typically better than -500)
    assert!(
        best_late > early + 500.0,
        "no learning: early {early:.0} best_late {best_late:.0} ({returns:?})"
    );
    assert!(best_late > -800.0, "final return too weak: {best_late:.0}");
}

#[test]
fn vectorized_sampling_learns_too() {
    // 2 workers x 4 lockstep envs at the same sample budget: the batched
    // hot loop must not change what the learner sees structurally —
    // returns improve just like the one-env-per-worker configuration.
    let mut cfg = learn_cfg(2, 3);
    cfg.envs_per_sampler = 4;
    let returns = run_returns(&cfg);
    let early = mean_f32(&returns[..3]);
    let best_late = returns[returns.len() / 2..]
        .windows(5)
        .map(mean_f32)
        .fold(f32::NEG_INFINITY, f32::max);
    assert!(
        best_late > early + 500.0,
        "no learning with envs_per_sampler=4: early {early:.0} best_late {best_late:.0}"
    );
    assert!(best_late > -800.0, "final return too weak: {best_late:.0}");
}

#[test]
fn parallel_sampling_does_not_hurt_learning() {
    // Same sample budget per iteration with N=1 vs N=6: final returns must
    // be in the same band (the paper's core "no return degradation" claim).
    let best = |r: &Vec<f32>| {
        r[r.len() / 2..]
            .windows(5)
            .map(mean_f32)
            .fold(f32::NEG_INFINITY, f32::max)
    };
    let r1 = run_returns(&learn_cfg(1, 1));
    let r6 = run_returns(&learn_cfg(6, 1));
    let (t1, t6) = (best(&r1), best(&r6));
    assert!(
        (t1 - t6).abs() < 450.0,
        "N=6 diverged from N=1 baseline: {t1:.0} vs {t6:.0}"
    );
    // and both actually learned
    assert!(t1 > -800.0 && t6 > -800.0, "t1={t1:.0} t6={t6:.0}");
}
