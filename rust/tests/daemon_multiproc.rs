//! Multi-process serving-tier acceptance: real `walle sample` child
//! PROCESSES against an in-test policy daemon, checked for bitwise
//! parity with the in-process threads topology.
//!
//! * The tentpole contract: per-(worker, env_slot) experience-chunk
//!   streams are bitwise identical between `--fleet-mode threads` and
//!   `--fleet-mode procs` at N=2 x M=2, for PPO and DDPG, across
//!   mid-run policy publishes — the transport is a pure topology knob
//!   because the MLP forward is row-independent and exploration noise
//!   is drawn client-side from each worker's own RNG streams.
//! * The fingerprint handshake rejects a client launched for a
//!   different run (seed skew here) with an actionable message on both
//!   ends, and the daemon keeps serving correct clients afterwards.
//! * The daemon survives SIGKILL of a sampler child: the slot's
//!   ActorClient is parked and re-claimed, a respawned child finishes
//!   the run, and the wire metrics record the disconnect.
//! * A full `Session` run under `--fleet-mode procs` completes with the
//!   scripted chunk-count kill switch tripping every child once
//!   (respawns strip the switch), and the merged `InferenceReport`
//!   carries the wire counters.
//!
//! Children are spawned from the REAL `walle` binary via `WALLE_BIN`
//! (`current_exe` inside a test resolves to the test harness, not the
//! CLI). CI runs this file under a hard `timeout` like the chaos suite:
//! a cross-process deadlock shows up as a timeout kill.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};

use walle::algo::api::algorithm_from_config;
use walle::algo::normalizer::NormSnapshot;
use walle::algo::rollout::ExperienceChunk;
use walle::config::{Algo, FleetMode, InferShards, InferWait, InferenceMode, TrainConfig};
use walle::coordinator::policy_store::PolicyStore;
use walle::coordinator::queue::Channel;
use walle::coordinator::sampler::{run_algo_sampler, PolicySource, SamplerCfg};
use walle::env::vec_env::VecEnv;
use walle::nn::layout::actor_layout;
use walle::runtime::daemon::{self, DaemonCtx};
use walle::runtime::{make_factory, BackendFactory};
use walle::session::Session;

const VERSIONS: u64 = 3;

/// The acceptance fleet: sync barrier mode, N=2 workers x M=2 envs,
/// S=2 shards, 320 samples per policy version in 40-step chunks (so
/// every worker delivers exactly 2 chunks per env per version).
fn fleet_cfg(algo: Algo) -> TrainConfig {
    let mut cfg = TrainConfig::preset("pendulum");
    cfg.backend = walle::config::Backend::Native;
    cfg.algo = algo;
    cfg.samplers = 2;
    cfg.envs_per_sampler = 2;
    cfg.seed = 29;
    cfg.async_mode = false;
    cfg.inference_mode = InferenceMode::Shared;
    cfg.infer_shards = InferShards::Fixed(2);
    cfg.infer_wait = InferWait::Fixed(2000);
    cfg.samples_per_iter = 320;
    cfg.chunk_steps = 40;
    cfg.iterations = 3;
    cfg.hidden = vec![8, 8];
    cfg.ppo.epochs = 2;
    cfg.ppo.minibatch = 128;
    cfg.fleet_mode = FleetMode::Procs;
    cfg
}

/// Deterministic per-version policy parameters: a constant vector of the
/// right length for the algorithm (full PPO flat vector, or the DDPG
/// actor), different per version so a publish is observable in the
/// chunk streams.
fn deterministic_params(cfg: &TrainConfig, v: u64) -> Vec<f32> {
    let factory = make_factory(cfg).unwrap();
    let n = match cfg.algo {
        Algo::Ppo => factory.ppo_param_count(),
        _ => actor_layout(factory.obs_dim(), factory.act_dim(), &cfg.hidden).total(),
    };
    vec![0.001 * (v as f32 + 1.0); n]
}

/// The pseudo-learner both harnesses share: publish version 1, then for
/// each version pop chunks off the experience queue until the fleet-wide
/// sample budget is met and publish the next version — at least
/// `VERSIONS - 1` MID-RUN publishes, which is what the parity claim is
/// about. Returns every popped chunk in arrival order.
fn drive_versions(
    cfg: &TrainConfig,
    queue: &Channel<ExperienceChunk>,
    store: &PolicyStore,
    per_version_samples: usize,
) -> Vec<ExperienceChunk> {
    let obs_dim = make_factory(cfg).unwrap().obs_dim();
    let mut all = Vec::new();
    store.publish(deterministic_params(cfg, 1), NormSnapshot::identity(obs_dim));
    for v in 1..=VERSIONS {
        let mut got = 0usize;
        while got < per_version_samples {
            let c = queue.pop().expect("experience queue closed mid-run");
            got += c.rew.len();
            all.push(c);
        }
        if v < VERSIONS {
            store.publish(
                deterministic_params(cfg, v + 1),
                NormSnapshot::identity(obs_dim),
            );
        }
    }
    all
}

fn by_lane(chunks: Vec<ExperienceChunk>) -> BTreeMap<(usize, usize), Vec<ExperienceChunk>> {
    let mut m: BTreeMap<(usize, usize), Vec<ExperienceChunk>> = BTreeMap::new();
    for c in chunks {
        m.entry((c.sampler_id, c.env_slot)).or_default().push(c);
    }
    m
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Bitwise stream comparison on the deterministic lanes (version, obs,
/// act, rew, logp, value, end, bootstrap). Timing-dependent fields
/// (busy_secs, episode bookkeeping granularity) are not part of the
/// contract.
fn assert_streams_equal(
    threads: &BTreeMap<(usize, usize), Vec<ExperienceChunk>>,
    procs: &BTreeMap<(usize, usize), Vec<ExperienceChunk>>,
) {
    let tk: Vec<_> = threads.keys().collect();
    let pk: Vec<_> = procs.keys().collect();
    assert_eq!(tk, pk, "both topologies must produce the same lanes");
    for (key, a) in threads {
        let b = &procs[key];
        assert_eq!(a.len(), b.len(), "chunk count for lane {key:?}");
        for (i, (c, d)) in a.iter().zip(b.iter()).enumerate() {
            let at = format!("lane {key:?} chunk {i}");
            assert_eq!(c.policy_version, d.policy_version, "policy_version @ {at}");
            assert_eq!(bits(&c.obs), bits(&d.obs), "obs @ {at}");
            assert_eq!(bits(&c.act), bits(&d.act), "act @ {at}");
            assert_eq!(bits(&c.rew), bits(&d.rew), "rew @ {at}");
            assert_eq!(bits(&c.logp), bits(&d.logp), "logp @ {at}");
            assert_eq!(bits(&c.value), bits(&d.value), "value @ {at}");
            assert_eq!(c.end, d.end, "end @ {at}");
            assert_eq!(
                c.bootstrap_value.to_bits(),
                d.bootstrap_value.to_bits(),
                "bootstrap_value @ {at}"
            );
        }
    }
}

/// Reference topology: the in-process shared pool with sampler THREADS,
/// exactly the orchestrator's shape but driven by the pseudo-learner.
fn threads_streams(cfg: &TrainConfig) -> BTreeMap<(usize, usize), Vec<ExperienceChunk>> {
    let factory = make_factory(cfg).unwrap();
    let algo = algorithm_from_config(cfg);
    let factory = &*factory;
    let algo = &*algo;
    let queue: Channel<ExperienceChunk> = Channel::new(cfg.queue_capacity);
    let store = PolicyStore::new();
    let stop = AtomicBool::new(false);
    let budget = (cfg.samples_per_iter + cfg.samplers - 1) / cfg.samplers;
    let m = cfg.envs_per_sampler;
    let pool = daemon::build_pool(cfg, factory);
    let mut collected = Vec::new();
    std::thread::scope(|scope| {
        // clients registered BEFORE serve threads start
        let clients: Vec<_> = (0..cfg.samplers).map(|id| pool.client(id)).collect();
        for shard in pool.shards() {
            let shard = shard.clone();
            let store = &store;
            scope.spawn(move || shard.serve_algo(algo, factory, store).unwrap());
        }
        for (id, client) in clients.into_iter().enumerate() {
            let scfg = SamplerCfg {
                id,
                seed: cfg.seed,
                chunk_steps: cfg.chunk_steps,
                sync_budget: Some(budget),
                reward_scale: cfg.reward_scale,
            };
            let venv = VecEnv::from_registry(&cfg.env, m, cfg.seed, (id * m) as u64 + 1).unwrap();
            let store = &store;
            let queue = &queue;
            let stop = &stop;
            scope.spawn(move || {
                run_algo_sampler(
                    algo,
                    scfg,
                    venv,
                    PolicySource::Shared(client),
                    store,
                    queue,
                    stop,
                )
            });
        }
        collected = drive_versions(cfg, &queue, &store, cfg.samples_per_iter);
        stop.store(true, Ordering::Relaxed);
        queue.close();
    });
    by_lane(collected)
}

/// The serving-tier topology: the same pool behind the daemon's accept
/// loop, with REAL `walle sample` child processes as the samplers.
fn procs_streams(cfg: &TrainConfig) -> BTreeMap<(usize, usize), Vec<ExperienceChunk>> {
    std::env::set_var("WALLE_BIN", env!("CARGO_BIN_EXE_walle"));
    let factory = make_factory(cfg).unwrap();
    let algo = algorithm_from_config(cfg);
    let factory = &*factory;
    let algo = &*algo;
    let queue: Channel<ExperienceChunk> = Channel::new(cfg.queue_capacity);
    let store = PolicyStore::new();
    let stop = AtomicBool::new(false);
    let sock = daemon::default_socket_path();
    let listener = daemon::bind_socket(&sock).unwrap();
    let sidecar = daemon::config_sidecar(&sock);
    cfg.save(sidecar.to_str().unwrap()).unwrap();
    let bin = daemon::walle_binary().unwrap();
    let pool = daemon::build_pool(cfg, factory);
    let ctx = DaemonCtx::new(cfg, pool.clone(), &store, &queue, &stop);
    let metrics = ctx.metrics.clone();
    let mut collected = Vec::new();
    let mut children = Vec::new();
    std::thread::scope(|scope| {
        for shard in pool.shards() {
            let shard = shard.clone();
            let store = &store;
            scope.spawn(move || shard.serve_algo(algo, factory, store).unwrap());
        }
        scope.spawn(move || daemon::accept_loop(scope, listener, ctx));
        for id in 0..cfg.samplers {
            children.push(daemon::spawn_sampler(&bin, &sock, &sidecar, id, false).unwrap());
        }
        collected = drive_versions(cfg, &queue, &store, cfg.samples_per_iter);
        stop.store(true, Ordering::Relaxed);
        queue.close();
    });
    for (id, child) in children.into_iter().enumerate() {
        daemon::terminate_child(child, id);
    }
    let _ = std::fs::remove_file(&sock);
    let _ = std::fs::remove_file(&sidecar);
    // every child ran one actor + one subscriber handshake over the wire
    let mut rep = pool.report();
    metrics.merge_into(&mut rep);
    assert!(
        rep.wire_handshakes >= (2 * cfg.samplers) as u64,
        "expected an actor + subscriber handshake per child, got {}",
        rep.wire_handshakes
    );
    assert!(rep.has_wire_traffic());
    by_lane(collected)
}

/// Tentpole acceptance (PPO): bitwise-identical per-(worker, env_slot)
/// chunk streams, threads vs processes, across two mid-run publishes.
#[test]
fn ppo_chunk_streams_bitwise_identical_threads_vs_procs() {
    let cfg = fleet_cfg(Algo::Ppo);
    let threads = threads_streams(&cfg);
    let procs = procs_streams(&cfg);
    assert_eq!(threads.len(), 4, "2 workers x 2 env slots");
    // every lane saw all three versions (the publishes were mid-run)
    for lane in threads.values() {
        let versions: Vec<u64> = lane.iter().map(|c| c.policy_version).collect();
        assert_eq!(versions, vec![1, 1, 2, 2, 3, 3], "lanes: {versions:?}");
    }
    assert_streams_equal(&threads, &procs);
}

/// Tentpole acceptance (DDPG): same contract on the deterministic-actor
/// + client-side-noise path.
#[test]
fn ddpg_chunk_streams_bitwise_identical_threads_vs_procs() {
    let cfg = fleet_cfg(Algo::Ddpg);
    let threads = threads_streams(&cfg);
    let procs = procs_streams(&cfg);
    assert_eq!(threads.len(), 4, "2 workers x 2 env slots");
    assert_streams_equal(&threads, &procs);
}

/// Handshake acceptance: a child launched for a different run (seed
/// skew) is rejected with an actionable message on BOTH ends, and the
/// daemon keeps serving a correct client afterwards.
#[test]
fn handshake_rejects_fingerprint_mismatch_and_daemon_survives() {
    std::env::set_var("WALLE_BIN", env!("CARGO_BIN_EXE_walle"));
    let cfg = fleet_cfg(Algo::Ppo);
    let factory = make_factory(&cfg).unwrap();
    let algo = algorithm_from_config(&cfg);
    let factory = &*factory;
    let algo = &*algo;
    let queue: Channel<ExperienceChunk> = Channel::new(cfg.queue_capacity);
    let store = PolicyStore::new();
    let stop = AtomicBool::new(false);
    let sock = daemon::default_socket_path();
    let listener = daemon::bind_socket(&sock).unwrap();
    let sidecar = daemon::config_sidecar(&sock);
    cfg.save(sidecar.to_str().unwrap()).unwrap();
    // a second sidecar describing a DIFFERENT run
    let mut wrong = cfg.clone();
    wrong.seed = 31;
    let wrong_path = format!("{}.wrong.json", sidecar.to_str().unwrap());
    wrong.save(&wrong_path).unwrap();
    let pool = daemon::build_pool(&cfg, factory);
    let ctx = DaemonCtx::new(&cfg, pool.clone(), &store, &queue, &stop);
    let mut survivor = None;
    let mut collected = Vec::new();
    std::thread::scope(|scope| {
        for shard in pool.shards() {
            let shard = shard.clone();
            let store = &store;
            scope.spawn(move || shard.serve_algo(algo, factory, store).unwrap());
        }
        scope.spawn(move || daemon::accept_loop(scope, listener, ctx));

        // the mismatched child must fail its handshake loudly
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_walle"))
            .args(["sample", "--connect"])
            .arg(&sock)
            .args(["--config", &wrong_path, "--worker-id", "0"])
            .env_remove(daemon::EXIT_AFTER_CHUNKS_ENV)
            .output()
            .unwrap();
        assert!(
            !out.status.success(),
            "a fingerprint-mismatched child must exit nonzero"
        );
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(
            err.contains("rejected the handshake"),
            "client-side error must name the rejection, got: {err}"
        );
        assert!(
            err.contains("seed"),
            "client-side error must name the mismatched field, got: {err}"
        );

        // the daemon is unharmed: a correct child completes a version
        let bin = daemon::walle_binary().unwrap();
        survivor = Some(daemon::spawn_sampler(&bin, &sock, &sidecar, 0, false).unwrap());
        // one worker's budget of version-1 samples
        collected = {
            let obs_dim = make_factory(&cfg).unwrap().obs_dim();
            store.publish(deterministic_params(&cfg, 1), NormSnapshot::identity(obs_dim));
            let budget = (cfg.samples_per_iter + cfg.samplers - 1) / cfg.samplers;
            let mut got = 0usize;
            let mut all = Vec::new();
            while got < budget {
                let c = queue.pop().expect("queue closed early");
                got += c.rew.len();
                all.push(c);
            }
            all
        };
        stop.store(true, Ordering::Relaxed);
        queue.close();
    });
    daemon::terminate_child(survivor.unwrap(), 0);
    let _ = std::fs::remove_file(&sock);
    let _ = std::fs::remove_file(&sidecar);
    let _ = std::fs::remove_file(&wrong_path);
    assert!(
        collected.iter().all(|c| c.policy_version == 1 && c.sampler_id == 0),
        "survivor chunks must come from worker 0 at version 1"
    );
}

/// Fault-tolerance acceptance: SIGKILL one sampler child mid-run; the
/// daemon parks the slot's client, a respawned child re-claims it, and
/// the run completes all versions. The wire metrics record the
/// disconnect.
#[test]
fn daemon_survives_sigkilled_child_and_respawn_completes() {
    std::env::set_var("WALLE_BIN", env!("CARGO_BIN_EXE_walle"));
    let cfg = fleet_cfg(Algo::Ppo);
    let factory = make_factory(&cfg).unwrap();
    let algo = algorithm_from_config(&cfg);
    let factory = &*factory;
    let algo = &*algo;
    let obs_dim = factory.obs_dim();
    let queue: Channel<ExperienceChunk> = Channel::new(cfg.queue_capacity);
    let store = PolicyStore::new();
    let stop = AtomicBool::new(false);
    let sock = daemon::default_socket_path();
    let listener = daemon::bind_socket(&sock).unwrap();
    let sidecar = daemon::config_sidecar(&sock);
    cfg.save(sidecar.to_str().unwrap()).unwrap();
    let bin = daemon::walle_binary().unwrap();
    let pool = daemon::build_pool(&cfg, factory);
    let ctx = DaemonCtx::new(&cfg, pool.clone(), &store, &queue, &stop);
    let metrics = ctx.metrics.clone();
    let mut children = Vec::new();
    let mut total = 0usize;
    std::thread::scope(|scope| {
        for shard in pool.shards() {
            let shard = shard.clone();
            let store = &store;
            scope.spawn(move || shard.serve_algo(algo, factory, store).unwrap());
        }
        scope.spawn(move || daemon::accept_loop(scope, listener, ctx));
        for id in 0..cfg.samplers {
            children.push(daemon::spawn_sampler(&bin, &sock, &sidecar, id, false).unwrap());
        }
        store.publish(deterministic_params(&cfg, 1), NormSnapshot::identity(obs_dim));

        // let the fleet make some progress, then SIGKILL child 0
        let mut got = 0usize;
        while got < 80 {
            let c = queue.pop().unwrap();
            got += c.rew.len();
        }
        total += got;
        children[0].kill().unwrap();
        let _ = children[0].wait();
        children[0] = daemon::spawn_sampler(&bin, &sock, &sidecar, 0, false).unwrap();

        // the survivor stalls at its budget (160); the replacement
        // delivers a full budget of its own, so >= 320 version-1 samples
        // always arrive; then two more full versions
        for v in 1..=VERSIONS {
            while total < (v as usize) * cfg.samples_per_iter {
                let c = queue.pop().expect("queue closed early — fleet did not heal");
                total += c.rew.len();
            }
            if v < VERSIONS {
                store.publish(
                    deterministic_params(&cfg, v + 1),
                    NormSnapshot::identity(obs_dim),
                );
            }
        }
        stop.store(true, Ordering::Relaxed);
        queue.close();
    });
    for (id, child) in children.into_iter().enumerate() {
        daemon::terminate_child(child, id);
    }
    let _ = std::fs::remove_file(&sock);
    let _ = std::fs::remove_file(&sidecar);
    assert!(total >= VERSIONS as usize * cfg.samples_per_iter);
    let mut rep = pool.report();
    metrics.merge_into(&mut rep);
    assert!(
        rep.wire_disconnects >= 1,
        "the SIGKILLed child must be counted as a disconnect, got {}",
        rep.wire_disconnects
    );
    assert!(
        rep.wire_handshakes >= (2 * cfg.samplers + 1) as u64,
        "the respawned child adds handshakes, got {}",
        rep.wire_handshakes
    );
}

/// End-to-end acceptance: a full `Session` training run under
/// `--fleet-mode procs` with the scripted chunk-count kill switch —
/// every child dies once, the reapers respawn them (stripping the
/// switch), the run completes, and the merged report carries the wire
/// counters into render().
#[test]
fn procs_train_completes_and_respawns_scripted_deaths() {
    std::env::set_var("WALLE_BIN", env!("CARGO_BIN_EXE_walle"));
    std::env::set_var(daemon::EXIT_AFTER_CHUNKS_ENV, "2");
    let cfg = fleet_cfg(Algo::Ppo);
    let session = Session::builder().config(cfg).quiet().build().unwrap();
    let result = session.run();
    std::env::remove_var(daemon::EXIT_AFTER_CHUNKS_ENV);
    let result = result.unwrap();
    assert_eq!(result.metrics.len(), 3, "the run must complete all iterations");
    assert_eq!(
        result.restarts, 2,
        "each of the 2 children dies exactly once on the scripted kill switch"
    );
    let rep = result.infer.expect("a procs run must carry an inference report");
    assert_eq!(rep.restarts, 2);
    assert!(rep.wire_frames_in > 0 && rep.wire_frames_out > 0);
    assert!(rep.wire_bytes_in > 0 && rep.wire_bytes_out > 0);
    assert!(
        rep.wire_handshakes >= 6,
        "2 children x (actor + subscriber) + 2 respawns, got {}",
        rep.wire_handshakes
    );
    assert!(rep.wire_disconnects >= 2, "got {}", rep.wire_disconnects);
    let rendered = rep.render();
    assert!(
        rendered.contains("wire traffic:"),
        "fleet health must render the wire counters: {rendered}"
    );
}
