//! `Session` builder validation + `SessionSpec` JSON round-trip +
//! TD3-through-the-builder end-to-end — the API-redesign acceptance
//! tests.

use walle::algo::ddpg::Ddpg;
use walle::algo::ppo::Ppo;
use walle::algo::sac::Sac;
use walle::algo::td3::Td3;
use walle::config::{InferShards, InferWait, ReplayStrategy, SacCfg, Td3Cfg, TrainConfig};
use walle::session::{Infer, Session, SessionSpec};
use walle::util::json::Json;

// ---------------------------------------------------- builder validation

#[test]
fn builder_rejects_more_infer_shards_than_samplers() {
    let err = Session::builder()
        .env("pendulum")
        .algo(Ppo::default())
        .samplers(2)
        .infer(Infer::Shared {
            shards: InferShards::Fixed(8),
        })
        .build()
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("infer_shards") && err.contains("samplers"),
        "error must name both knobs: {err}"
    );
    // auto always resolves to a valid count
    Session::builder()
        .env("pendulum")
        .algo(Ppo::default())
        .samplers(2)
        .infer(Infer::Shared {
            shards: InferShards::Auto,
        })
        .build()
        .unwrap();
}

#[test]
fn builder_rejects_ppo_only_knobs_under_replay_algorithms() {
    for (name, build) in [
        (
            "ddpg",
            Session::builder()
                .env("pendulum")
                .algo(Ddpg::default())
                .learner_shards(4)
                .build(),
        ),
        (
            "td3",
            Session::builder()
                .env("pendulum")
                .algo(Td3::default())
                .max_staleness(5)
                .build(),
        ),
    ] {
        let err = build.unwrap_err().to_string();
        assert!(
            err.contains("PPO-only") && err.contains(name),
            "{name}: error must say what is PPO-only and which algorithm \
             the session runs: {err}"
        );
    }
    // the same knobs are fine under PPO
    Session::builder()
        .env("pendulum")
        .algo(Ppo::default())
        .learner_shards(2)
        .max_staleness(5)
        .build()
        .unwrap();
}

#[test]
fn builder_rejects_zero_env_specs() {
    for build in [
        Session::builder().env("pendulum").samplers(0).build(),
        Session::builder().env("pendulum").envs_per_sampler(0).build(),
        Session::builder().env("pendulum").samples_per_iter(0).build(),
    ] {
        assert!(build.is_err(), "zero-env spec must be rejected at build()");
    }
}

#[test]
fn builder_rejects_td3_on_xla_backend() {
    let err = Session::builder()
        .env("pendulum")
        .algo(Td3::default())
        .backend(walle::config::Backend::Xla)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("td3") && err.contains("native"), "{err}");
}

#[test]
fn builder_rejects_sac_on_xla_backend() {
    let err = Session::builder()
        .env("pendulum")
        .algo(Sac::default())
        .backend(walle::config::Backend::Xla)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("sac") && err.contains("native"), "{err}");
}

/// The PR 8 replay/learner knobs are off-policy-only: setting any of
/// them under PPO fails at build() with an error that says so, while the
/// full combination is accepted under a replay algorithm.
#[test]
fn builder_rejects_off_policy_knobs_under_ppo() {
    for build in [
        Session::builder()
            .env("pendulum")
            .algo(Ppo::default())
            .replay_shards(4)
            .build(),
        Session::builder()
            .env("pendulum")
            .algo(Ppo::default())
            .learner_threads(2)
            .build(),
        Session::builder()
            .env("pendulum")
            .algo(Ppo::default())
            .replay_strategy(ReplayStrategy::Prioritized)
            .build(),
    ] {
        let err = build.unwrap_err().to_string();
        assert!(err.contains("off-policy-only"), "{err}");
    }
    // the full stack is valid under a replay learner
    Session::builder()
        .env("pendulum")
        .algo(Ddpg::default())
        .replay_shards(4)
        .learner_threads(2)
        .replay_strategy(ReplayStrategy::Prioritized)
        .build()
        .unwrap();
}

/// `.algo(X::default())` selects the algorithm WITHOUT clobbering the
/// env preset's tuned hyper-parameter section (pendulum's PPO preset
/// tunes lr/minibatch; a default Ppo instance must not reset them).
#[test]
fn default_algo_instance_preserves_preset_tuning() {
    let session = Session::builder()
        .env("pendulum")
        .algo(Ppo::default())
        .build()
        .unwrap();
    let preset = TrainConfig::preset("pendulum");
    assert_eq!(session.config().ppo, preset.ppo, "preset PPO tuning lost");
    assert_eq!(session.config().ppo.lr, 1e-3);
    assert_eq!(session.config().ppo.minibatch, 256);
}

#[test]
fn builder_folds_algorithm_hyperparams_into_config() {
    let session = Session::builder()
        .env("pendulum")
        .algo(Td3 {
            cfg: Td3Cfg {
                policy_delay: 5,
                target_noise: 0.3,
                ..Default::default()
            },
        })
        .build()
        .unwrap();
    assert_eq!(session.config().algo.name(), "td3");
    assert_eq!(session.config().td3.policy_delay, 5);
    assert_eq!(session.spec().algo, "td3");
    let j = session.spec().hyperparams.clone();
    assert_eq!(j.get("policy_delay").unwrap().as_usize().unwrap(), 5);
}

// ------------------------------------------------------- spec round-trip

#[test]
fn session_spec_round_trips_to_json() {
    let mut cfg = TrainConfig::preset("pendulum");
    cfg.algo = walle::config::Algo::Ddpg;
    cfg.inference_mode = walle::config::InferenceMode::Shared;
    cfg.infer_shards = InferShards::Fixed(2);
    cfg.infer_wait = InferWait::Fixed(750);
    let session = Session::from_config(cfg).unwrap();
    let spec = session.spec().clone();
    assert_eq!(spec.infer.shards, Some(2));
    assert_eq!(spec.infer.wait, "fixed:750");
    let j = spec.to_json();
    let back = SessionSpec::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
    assert_eq!(spec, back, "SessionSpec must survive a JSON round-trip");
}

/// The legacy `infer_max_wait_us` config key still resolves through the
/// spec path (satellite regression).
#[test]
fn session_spec_accepts_legacy_infer_max_wait_us() {
    let j = Json::parse(
        r#"{"env": "pendulum", "inference_mode": "shared", "infer_max_wait_us": 750}"#,
    )
    .unwrap();
    let spec = SessionSpec::from_json(&j).unwrap();
    assert_eq!(spec.infer.wait, "fixed:750");
    assert_eq!(spec.config.infer_wait, InferWait::Fixed(750));
    // and the modern spelling round-trips from there
    let back =
        SessionSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap()).unwrap();
    assert_eq!(spec, back);
}

#[test]
fn spec_renders_resolved_topology_without_algo_matches() {
    for algo in ["ppo", "ddpg", "td3", "sac"] {
        let mut cfg = TrainConfig::preset("pendulum");
        cfg.algo = walle::config::Algo::parse(algo).unwrap();
        let session = Session::from_config(cfg).unwrap();
        let text = session.spec().render();
        assert!(text.contains(algo), "{algo}: {text}");
        assert!(text.contains("pendulum"));
        assert!(text.contains("local"), "default inference is local: {text}");
    }
}

// -------------------------------------------------------- TD3 end-to-end

/// Tentpole acceptance: TD3 trains end-to-end on pendulum via
/// `Session::builder()`, implemented entirely against the `Algorithm`
/// trait (no sampler/orchestrator/inference-server edits).
#[test]
fn td3_trains_end_to_end_on_pendulum_via_builder() {
    let session = Session::builder()
        .env("pendulum")
        .algo(Td3 {
            cfg: Td3Cfg {
                warmup_steps: 100,
                batch: 32,
                updates_per_iter: 10,
                ..Default::default()
            },
        })
        .samplers(2)
        .samples_per_iter(300)
        .iterations(3)
        .chunk_steps(100)
        .hidden(&[16, 16])
        .seed(7)
        .quiet()
        .build()
        .unwrap();

    let result = session.run().unwrap();
    assert_eq!(result.metrics.len(), 3);
    for m in &result.metrics {
        assert!(m.samples >= 300);
        assert!(m.learn_secs >= 0.0);
    }
    // final params are the TD3 actor (same layout as the DDPG actor)
    let actor_len = walle::nn::layout::actor_layout(3, 1, &[16, 16]).total();
    assert_eq!(result.final_params.len(), actor_len);
    assert!(result.final_params.iter().all(|p| p.is_finite()));
    // after warmup the learner must have moved the actor off its init
    let init = walle::nn::layout::actor_layout(3, 1, &[16, 16])
        .init_flat(&mut walle::util::rng::Pcg64::new(7));
    assert_ne!(result.final_params, init, "actor never updated");

    // deterministic eval flows through the same trait actor, under the
    // normalizer snapshot the run actually trained with
    let eval = session
        .evaluate_with_norm(&result.final_params, &result.final_norm, 3)
        .unwrap();
    assert_eq!(eval.returns.len(), 3);
    assert!(eval.mean_return.is_finite());
    // the bare-checkpoint path (identity norm) also works
    assert!(session.evaluate(&result.final_params, 1).is_ok());
}

/// TD3 also runs through the shared (sharded) inference pool — served by
/// the SAME generic pool code that serves PPO and DDPG.
#[test]
fn td3_runs_under_shared_inference() {
    let session = Session::builder()
        .env("pendulum")
        .algo(Td3 {
            cfg: Td3Cfg {
                warmup_steps: 100,
                batch: 32,
                updates_per_iter: 5,
                ..Default::default()
            },
        })
        .samplers(2)
        .samples_per_iter(300)
        .iterations(2)
        .chunk_steps(100)
        .hidden(&[16, 16])
        .infer(Infer::Shared {
            shards: InferShards::Fixed(2),
        })
        .infer_wait(InferWait::Fixed(500))
        .quiet()
        .build()
        .unwrap();
    let result = session.run().unwrap();
    assert_eq!(result.metrics.len(), 2);
    let rep = result.infer.expect("shared mode must report");
    assert!(rep.forwards > 0);
    assert_eq!(rep.shards, 2);
}

// -------------------------------------------------------- SAC end-to-end

/// PR 8 acceptance: SAC trains end-to-end on pendulum purely against the
/// `Algorithm` trait — zero edits to the sampler or the inference server
/// — with its twin soft critics fed from the sharded replay store and
/// its learned temperature adapting from `init_alpha`.
#[test]
fn sac_trains_end_to_end_on_pendulum_via_builder() {
    let session = Session::builder()
        .env("pendulum")
        .algo(Sac {
            cfg: SacCfg {
                warmup_steps: 100,
                batch: 32,
                updates_per_iter: 10,
                ..Default::default()
            },
        })
        .samplers(2)
        .samples_per_iter(300)
        .iterations(3)
        .chunk_steps(100)
        .hidden(&[16, 16])
        .replay_shards(2)
        .seed(7)
        .quiet()
        .build()
        .unwrap();

    let result = session.run().unwrap();
    assert_eq!(result.metrics.len(), 3);
    // final params are the SAC actor: a 2*act_dim head (mean + log-std)
    let actor_len = walle::nn::layout::actor_layout(3, 2, &[16, 16]).total();
    assert_eq!(result.final_params.len(), actor_len);
    assert!(result.final_params.iter().all(|p| p.is_finite()));
    // updates ran: the entropy bonus is measured from real log-probs
    let last = result.metrics.last().unwrap();
    assert!(last.entropy.is_finite() && last.entropy != 0.0, "no SAC updates ran");

    // deterministic mean-action eval through the same trait actor
    let eval = session
        .evaluate_with_norm(&result.final_params, &result.final_norm, 3)
        .unwrap();
    assert_eq!(eval.returns.len(), 3);
    assert!(eval.mean_return.is_finite());
    let eval2 = session
        .evaluate_with_norm(&result.final_params, &result.final_norm, 3)
        .unwrap();
    assert_eq!(eval.returns, eval2.returns, "SAC eval must be deterministic");
}

/// SAC also runs through the shared (sharded) inference pool — served by
/// the same generic pool code as the other three algorithms.
#[test]
fn sac_runs_under_shared_inference() {
    let session = Session::builder()
        .env("pendulum")
        .algo(Sac {
            cfg: SacCfg {
                warmup_steps: 100,
                batch: 32,
                updates_per_iter: 5,
                ..Default::default()
            },
        })
        .samplers(2)
        .samples_per_iter(300)
        .iterations(2)
        .chunk_steps(100)
        .hidden(&[16, 16])
        .infer(Infer::Shared {
            shards: InferShards::Fixed(2),
        })
        .infer_wait(InferWait::Fixed(500))
        .quiet()
        .build()
        .unwrap();
    let result = session.run().unwrap();
    assert_eq!(result.metrics.len(), 2);
    let rep = result.infer.expect("shared mode must report");
    assert!(rep.forwards > 0);
    assert_eq!(rep.shards, 2);
}

/// A checkpoint of the wrong algorithm/shape is rejected with a message
/// naming the expectation (the old eval path silently assumed PPO).
#[test]
fn evaluate_rejects_wrong_param_count() {
    let session = Session::builder()
        .env("pendulum")
        .algo(Ddpg::default())
        .quiet()
        .build()
        .unwrap();
    let err = session.evaluate(&[0.0; 7], 1).unwrap_err().to_string();
    assert!(err.contains("ddpg") && err.contains("expects"), "{err}");
}
