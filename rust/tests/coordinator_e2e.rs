//! End-to-end coordinator runs on the XLA backend: N parallel samplers,
//! each with its own PJRT client, feeding the learner executing the AOT
//! train artifact — the production configuration of the paper's Fig 2,
//! shrunk to test scale. Requires `make artifacts`.

use walle::config::{Algo, Backend, InferShards, InferWait, InferenceMode, TrainConfig};
use walle::coordinator::metrics::MetricsLog;
use walle::coordinator::orchestrator;
use walle::runtime::make_factory;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/index.json").exists()
}

fn xla_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::preset("pendulum");
    cfg.backend = Backend::Xla;
    cfg.samplers = 3;
    cfg.samples_per_iter = 800;
    cfg.iterations = 2;
    cfg.chunk_steps = 100;
    cfg.ppo.epochs = 2;
    // hidden must match the artifacts (presets are 64x64)
    cfg.hidden = vec![64, 64];
    cfg
}

#[test]
fn xla_ppo_run_end_to_end() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = xla_cfg();
    let factory = make_factory(&cfg).unwrap();
    let mut log = MetricsLog::quiet();
    let r = orchestrator::run(&cfg, factory.as_ref(), &mut log).unwrap();
    assert_eq!(r.metrics.len(), 2);
    for m in &r.metrics {
        assert!(m.samples >= 800);
        assert!(m.learn_secs > 0.0);
        assert!(m.mean_return.is_finite());
        assert!(m.approx_kl.is_finite());
    }
    assert_eq!(r.sampler_reports.len(), 3);
    assert!(r.sampler_reports.iter().all(|s| s.steps > 0));
    // params are live (changed from init)
    let init = factory.init_ppo_params(cfg.seed);
    assert_ne!(r.final_params, init);
}

#[test]
fn xla_ddpg_run_end_to_end() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = xla_cfg();
    cfg.algo = Algo::Ddpg;
    cfg.samples_per_iter = 400;
    cfg.ddpg.warmup_steps = 200;
    cfg.ddpg.updates_per_iter = 4;
    let factory = make_factory(&cfg).unwrap();
    let mut log = MetricsLog::quiet();
    let r = orchestrator::run(&cfg, factory.as_ref(), &mut log).unwrap();
    assert_eq!(r.metrics.len(), 2);
    assert!(r.metrics.iter().all(|m| m.samples >= 400));
}

/// Shared mega-batch inference end-to-end on the native backend (runs
/// everywhere, no artifacts needed): the full coordinator with the
/// inference-server thread in the loop, checked for liveness, sample
/// accounting, and a sane dispatch report.
#[test]
fn native_shared_inference_run_end_to_end() {
    let mut cfg = xla_cfg();
    cfg.backend = Backend::Native;
    cfg.hidden = vec![16, 16];
    cfg.inference_mode = InferenceMode::Shared;
    cfg.infer_wait = InferWait::Fixed(500);
    cfg.envs_per_sampler = 2;
    let factory = make_factory(&cfg).unwrap();
    let mut log = MetricsLog::quiet();
    let r = orchestrator::run(&cfg, factory.as_ref(), &mut log).unwrap();
    assert_eq!(r.metrics.len(), 2);
    for m in &r.metrics {
        assert!(m.samples >= 800);
        assert!(m.mean_return.is_finite());
    }
    let rep = r.infer.expect("shared run must carry an inference report");
    assert_eq!(rep.fleet_rows, cfg.samplers * cfg.envs_per_sampler);
    assert!(rep.forwards > 0);
    let total_steps: u64 = r.sampler_reports.iter().map(|s| s.steps).sum();
    assert!(rep.rows >= total_steps);
    // coalescing must actually happen: strictly fewer forwards than rows
    assert!(rep.forwards < rep.rows, "server never batched anything");
}

/// Sharded + adaptive-wait configuration end-to-end on the native
/// backend: two shards serve four workers, the adaptive cut keeps the
/// run live, and the merged report accounts for the whole fleet.
#[test]
fn native_sharded_adaptive_inference_run_end_to_end() {
    let mut cfg = xla_cfg();
    cfg.backend = Backend::Native;
    cfg.hidden = vec![16, 16];
    cfg.samplers = 4;
    cfg.inference_mode = InferenceMode::Shared;
    cfg.infer_shards = InferShards::Fixed(2);
    cfg.infer_wait = InferWait::Adaptive;
    cfg.envs_per_sampler = 2;
    let factory = make_factory(&cfg).unwrap();
    let mut log = MetricsLog::quiet();
    let r = orchestrator::run(&cfg, factory.as_ref(), &mut log).unwrap();
    assert_eq!(r.metrics.len(), 2);
    for m in &r.metrics {
        assert!(m.samples >= 800);
        assert!(m.mean_return.is_finite());
    }
    let rep = r.infer.expect("sharded run must carry a merged report");
    assert_eq!(rep.shards, 2);
    assert_eq!(rep.fleet_rows, cfg.samplers * cfg.envs_per_sampler);
    assert!(rep.forwards > 0);
    assert!(rep.forwards < rep.rows, "shards never batched anything");
    // default pool-epoch mode: every dispatch records its snapshot lag,
    // and the learner's mid-run publishes exercise the flip barrier
    assert_eq!(rep.epoch_lag.count(), rep.forwards);
}

#[test]
fn xla_and_native_runs_have_same_shape() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // Both backends run the same coordinator; this catches interface drift
    // (e.g. batch-size assumptions) rather than numerics (covered by the
    // parity tests).
    let xla_cfg = xla_cfg();
    let mut native_cfg = xla_cfg.clone();
    native_cfg.backend = Backend::Native;

    for cfg in [xla_cfg, native_cfg] {
        let factory = make_factory(&cfg).unwrap();
        let mut log = MetricsLog::quiet();
        let r = orchestrator::run(&cfg, factory.as_ref(), &mut log).unwrap();
        assert_eq!(r.metrics.len(), 2, "backend {:?}", cfg.backend);
        assert_eq!(
            r.final_params.len(),
            factory.ppo_param_count(),
            "backend {:?}",
            cfg.backend
        );
    }
}
