//! Integration: the AOT/XLA path against the native oracle.
//!
//! Compiled only with `--features xla` (the published `xla` crate binds
//! xla_extension, which most CI/dev boxes don't carry); at runtime the
//! tests additionally skip unless `make artifacts` has run.
//!
//! These tests require `make artifacts` to have run (they are the
//! authentic consumer of the HLO text files): load each artifact through
//! PJRT, execute it, and compare numerics against the pure-Rust mirror,
//! which is itself finite-difference-verified in unit tests. Agreement
//! here certifies the whole Python→HLO→PJRT→Rust chain.

#![cfg(feature = "xla")]

use walle::config::{DdpgCfg, PpoCfg};
use walle::runtime::native_backend::NativeFactory;
use walle::runtime::xla_backend::XlaFactory;
use walle::runtime::{BackendFactory, DdpgBatch, DdpgTrainState, PpoMinibatch, PpoTrainState};
use walle::util::rng::Pcg64;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/index.json").exists()
}

fn xla_factory(preset: &str) -> XlaFactory {
    XlaFactory::new("artifacts", preset).expect("artifact load")
}

fn native_for(xf: &XlaFactory) -> NativeFactory {
    let m = xf.meta();
    NativeFactory::new(
        m.obs_dim,
        m.act_dim,
        &m.hidden,
        PpoCfg {
            clip: m.clip,
            ent_coef: m.ent_coef,
            vf_coef: m.vf_coef,
            gamma: m.gamma,
            lam: m.lam,
            ..Default::default()
        },
        DdpgCfg {
            gamma: m.ddpg.as_ref().map(|d| d.gamma).unwrap_or(0.99),
            tau: m.ddpg.as_ref().map(|d| d.tau).unwrap_or(0.005),
            ..Default::default()
        },
    )
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn act_artifact_matches_native_oracle() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let xf = xla_factory("pendulum");
    let nf = native_for(&xf);
    let flat = xf.init_ppo_params(42);
    let mut xa = xf.make_actor().unwrap();
    let mut na = nf.make_actor().unwrap();
    let b = xa.batch();
    let mut rng = Pcg64::new(1);
    for trial in 0..10 {
        let obs: Vec<f32> = (0..b * 3).map(|_| rng.normal()).collect();
        let noise: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let xr = xa.act(&flat, &obs, &noise).unwrap();
        let nr = na.act(&flat, &obs, &noise).unwrap();
        assert!(
            max_abs_diff(&xr.action, &nr.action) < 1e-4,
            "trial {trial}: actions diverge"
        );
        assert!(max_abs_diff(&xr.logp, &nr.logp) < 1e-3, "trial {trial}: logp");
        assert!(max_abs_diff(&xr.value, &nr.value) < 1e-4, "trial {trial}: value");
        assert!(max_abs_diff(&xr.mean, &nr.mean) < 1e-4, "trial {trial}: mean");
    }
}

#[test]
fn gae_artifact_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let xf = xla_factory("pendulum");
    let nf = native_for(&xf);
    let mut xl = xf.make_ppo_learner().unwrap();
    let mut nl = nf.make_ppo_learner().unwrap();
    let mut rng = Pcg64::new(2);
    // ragged lengths exercise the horizon padding path
    for t in [1usize, 7, 100, 200, 256] {
        let rew: Vec<f32> = (0..t).map(|_| rng.normal()).collect();
        let val: Vec<f32> = (0..=t).map(|_| rng.normal()).collect();
        let cont: Vec<f32> = (0..t)
            .map(|_| if rng.next_f32() < 0.1 { 0.0 } else { 1.0 })
            .collect();
        let (xa, xr) = xl.gae(&rew, &val, &cont).unwrap();
        let (na, nr) = nl.gae(&rew, &val, &cont).unwrap();
        assert_eq!(xa.len(), t);
        assert!(max_abs_diff(&xa, &na) < 1e-3, "T={t}: adv diverges");
        assert!(max_abs_diff(&xr, &nr) < 1e-3, "T={t}: ret diverges");
    }
}

#[test]
fn train_ppo_artifact_matches_native_step() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let xf = xla_factory("pendulum");
    let nf = native_for(&xf);
    let flat = xf.init_ppo_params(7);
    let mut xl = xf.make_ppo_learner().unwrap();
    let mut nl = nf.make_ppo_learner().unwrap();
    let m = xl.minibatch_size();
    let mut rng = Pcg64::new(3);

    // consistent synthetic batch: actions drawn from the policy itself
    let mut actor = nf.make_actor().unwrap();
    let obs: Vec<f32> = (0..m * 3).map(|_| rng.normal()).collect();
    let noise: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
    let out = actor.act(&flat, &obs, &noise).unwrap();
    let old_logp: Vec<f32> = out.logp.iter().map(|l| l - 0.1).collect();
    let adv: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
    let ret: Vec<f32> = out.value.iter().map(|v| v + 0.5).collect();
    // mask the tail to exercise exact padding semantics
    let mut mask = vec![1.0f32; m];
    for v in mask.iter_mut().skip(m - 16) {
        *v = 0.0;
    }
    let mb = PpoMinibatch {
        obs: &obs,
        act: &out.action,
        old_logp: &old_logp,
        adv: &adv,
        ret: &ret,
        mask: &mask,
    };

    let mut xs = PpoTrainState::new(flat.clone());
    let mut ns = PpoTrainState::new(flat);
    let xstats = xl.train_step(&mut xs, 3e-4, &mb).unwrap();
    let nstats = nl.train_step(&mut ns, 3e-4, &mb).unwrap();

    assert!((xstats.total - nstats.total).abs() < 2e-3, "{xstats:?} vs {nstats:?}");
    assert!((xstats.pi_loss - nstats.pi_loss).abs() < 2e-3);
    assert!((xstats.v_loss - nstats.v_loss).abs() < 2e-3);
    assert!((xstats.approx_kl - nstats.approx_kl).abs() < 1e-3);
    assert!((xstats.clip_frac - nstats.clip_frac).abs() < 1e-5);
    // updated parameters agree to float tolerance
    assert!(
        max_abs_diff(&xs.flat, &ns.flat) < 5e-4,
        "params diverged after one step: {}",
        max_abs_diff(&xs.flat, &ns.flat)
    );
    assert_eq!(xs.t, 1);

    // a few more steps should stay in lockstep
    for _ in 0..3 {
        xl.train_step(&mut xs, 3e-4, &mb).unwrap();
        nl.train_step(&mut ns, 3e-4, &mb).unwrap();
    }
    assert!(max_abs_diff(&xs.flat, &ns.flat) < 3e-3);
}

#[test]
fn grad_and_apply_artifacts_match_fused_step() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // halfcheetah is the preset with grad_ppo/apply_grads (§6.2)
    let xf = xla_factory("halfcheetah");
    let flat = xf.init_ppo_params(11);
    let mut xl = xf.make_ppo_learner().unwrap();
    let m = xl.minibatch_size();
    let (o, a) = (17usize, 6usize);
    let mut rng = Pcg64::new(5);
    let obs: Vec<f32> = (0..m * o).map(|_| rng.normal()).collect();
    let act: Vec<f32> = (0..m * a).map(|_| rng.normal()).collect();
    let old_logp: Vec<f32> = (0..m).map(|_| -8.0 - rng.next_f32()).collect();
    let adv: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
    let ret: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
    let mask = vec![1.0f32; m];
    let mb = PpoMinibatch {
        obs: &obs,
        act: &act,
        old_logp: &old_logp,
        adv: &adv,
        ret: &ret,
        mask: &mask,
    };

    let mut fused = PpoTrainState::new(flat.clone());
    let mut split = PpoTrainState::new(flat.clone());
    xl.train_step(&mut fused, 1e-3, &mb).unwrap();
    let (g, _loss, n) = xl.grad(&flat, &mb).unwrap();
    assert_eq!(n as usize, m);
    xl.apply_grads(&mut split, &g, 1e-3).unwrap();
    assert!(
        max_abs_diff(&fused.flat, &split.flat) < 5e-4,
        "grad+apply != fused train step: {}",
        max_abs_diff(&fused.flat, &split.flat)
    );
}

#[test]
fn ddpg_artifacts_match_native() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let xf = xla_factory("pendulum");
    let nf = native_for(&xf);
    let (actor, critic) = xf.init_ddpg_params(21);
    let d = xf.meta().ddpg.clone().unwrap();
    let b = d.batch;
    let mut rng = Pcg64::new(6);

    // actor forward parity
    let mut xa = xf.make_ddpg_actor().unwrap();
    let mut na = nf.make_ddpg_actor().unwrap();
    let ab = xa.batch();
    let obs1: Vec<f32> = (0..ab * 3).map(|_| rng.normal()).collect();
    let x_act = xa.act(&actor, &obs1).unwrap();
    let n_act = na.act(&actor, &obs1).unwrap();
    assert!(max_abs_diff(&x_act, &n_act) < 1e-4);

    // one fused train step parity
    let mut xl = xf.make_ddpg_learner().unwrap();
    let mut nl = nf.make_ddpg_learner().unwrap();
    let obs: Vec<f32> = (0..b * 3).map(|_| rng.normal()).collect();
    let act: Vec<f32> = (0..b).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let rew: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
    let next_obs: Vec<f32> = (0..b * 3).map(|_| rng.normal()).collect();
    let done: Vec<f32> = (0..b).map(|_| if rng.next_f32() < 0.1 { 1.0 } else { 0.0 }).collect();
    let batch = DdpgBatch {
        obs: &obs,
        act: &act,
        rew: &rew,
        next_obs: &next_obs,
        done: &done,
    };
    let mut xs = DdpgTrainState::new(actor.clone(), critic.clone());
    let mut ns = DdpgTrainState::new(actor, critic);
    let (xq, xpi) = xl.train_step(&mut xs, 1e-3, 1e-3, &batch).unwrap();
    let (nq, npi) = nl.train_step(&mut ns, 1e-3, 1e-3, &batch).unwrap();
    assert!((xq - nq).abs() < 2e-3, "q_loss {xq} vs {nq}");
    assert!((xpi - npi).abs() < 2e-3, "pi_loss {xpi} vs {npi}");
    assert!(max_abs_diff(&xs.actor, &ns.actor) < 5e-4);
    assert!(max_abs_diff(&xs.critic, &ns.critic) < 5e-4);
    assert!(max_abs_diff(&xs.targ_actor, &ns.targ_actor) < 5e-4);
}
