"""L1 Pallas kernel: fused tiled matmul + bias + activation, with custom VJP.

This is the compute hot-spot of the WALL-E policy/value networks: every
dense layer of the actor, critic and value MLPs (forward *and* backward)
runs through this kernel, so it dominates both the sampler `act` artifact
and the learner `train_ppo` artifact.

TPU mapping (see DESIGN.md "Hardware adaptation"):
  * the grid tiles (M, N, K) into VMEM-resident blocks whose trailing dims
    are (sublane, lane) = (8, 128) multiples, the MXU-friendly layout;
  * the K axis is the innermost grid dimension so each (i, j) output block
    stays resident in VMEM while partial products accumulate into it in
    f32 (``preferred_element_type``), which is what the MXU natively does;
  * bias add + activation are fused into the final K step, so the
    pre-activation never round-trips to HBM.

The kernel is wrapped in ``jax.custom_vjp`` whose backward pass reuses the
same Pallas matmul for dX = dZ @ W^T and dW = X^T @ dZ — the whole training
graph therefore lowers to Pallas kernels plus trivial glue.

On this image Pallas must run ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); the BlockSpec structure is still the TPU one.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default block shapes: (8, 128)-aligned, sized for the small policy MLPs
# (64-wide layers, minibatch <= 2048) so that most layers are single-block
# in N/K and only the batch axis is gridded.
DEF_BLOCK_M = 128
DEF_BLOCK_N = 128
DEF_BLOCK_K = 128

_INTERPRET = True  # CPU image: Mosaic lowering unavailable. See module doc.


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad)


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    """Grid = (M/bm, N/bn, K/bk); K innermost; o block revisited across K."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        y = o_ref[...] + b_ref[...]
        o_ref[...] = ref.apply_activation(y, activation)


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def fused_linear_fwd_impl(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "id",
    block_m: int = DEF_BLOCK_M,
    block_n: int = DEF_BLOCK_N,
    block_k: int = DEF_BLOCK_K,
) -> jax.Array:
    """act(x @ w + b) via the Pallas kernel. x:[M,K] w:[K,N] b:[N] -> [M,N]."""
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, (x.shape, w.shape)
    assert b.shape == (n,), (b.shape, n)

    bm = min(block_m, _ceil_mult(m, 8))
    bn = min(block_n, _ceil_mult(n, 128))
    bk = min(block_k, _ceil_mult(kdim, 128))

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(b, 0, bn)[None, :]  # [1, Np] so each (i,j) block can slice it

    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_linear_kernel, nk=grid[2], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=_INTERPRET,
    )(xp, wp, bp)
    return out[:m, :n].astype(x.dtype)


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def matmul(x: jax.Array, w: jax.Array, **kw) -> jax.Array:
    """Bias-free identity-activation matmul through the same kernel."""
    b = jnp.zeros((w.shape[1],), jnp.float32)
    return fused_linear_fwd_impl(x, w, b, activation="id", **kw)


# ---------------------------------------------------------------------------
# custom VJP: backward also runs on the Pallas matmul
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(
    x: jax.Array, w: jax.Array, b: jax.Array, activation: str = "id"
) -> jax.Array:
    """Differentiable fused dense layer: act(x @ w + b).

    Forward and backward both lower to the tiled Pallas matmul kernel.
    """
    return fused_linear_fwd_impl(x, w, b, activation=activation)


def _fused_linear_fwd(x, w, b, activation):
    y = fused_linear_fwd_impl(x, w, b, activation=activation)
    return y, (x, w, y)


def _fused_linear_bwd(activation, res, dy):
    x, w, y = res
    dz = dy * ref.activation_grad_from_out(y, activation)
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
