"""L1 Pallas kernel: generalized advantage estimation (reverse-time scan).

GAE has a strict sequential dependence along time, so there is no grid
parallelism to exploit: the kernel instead keeps the *entire* trajectory
(T <= 1024 floats per array, ~16 KiB total) resident in VMEM and runs the
recurrence with a single ``fori_loop`` — the TPU analogue of the paper's
single-pass CPU loop, with zero HBM traffic between steps.

    delta_t = r_t + gamma * cont_t * V_{t+1} - V_t          (vectorized)
    adv_t   = delta_t + gamma * lam * cont_t * adv_{t+1}    (reverse scan)
    ret_t   = adv_t + V_t                                   (vectorized)

Arrays are carried as [1, T] (lane-major) so the vectorized pre/post steps
map onto the VPU's (8, 128) registers; the scan reads/writes single lanes.

Correctness oracle: ``ref.gae_ref`` (pure jnp scan), swept by hypothesis in
``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INTERPRET = True  # CPU image — see fused_linear.py


def _gae_kernel(rew_ref, val_ref, cont_ref, adv_ref, ret_ref, *, gamma, lam, t_len):
    rew = rew_ref[0, :]
    val_now = val_ref[0, :t_len]
    val_next = val_ref[0, 1:]
    cont = cont_ref[0, :]

    # Vectorized TD residuals (VPU).
    delta = rew + gamma * cont * val_next - val_now

    # Reverse sequential scan (unavoidable dependence).
    def body(i, carry):
        t = t_len - 1 - i
        a = delta[t] + gamma * lam * cont[t] * carry
        adv_ref[0, t] = a
        return a

    jax.lax.fori_loop(0, t_len, body, jnp.float32(0.0))

    # Vectorized returns.
    ret_ref[0, :] = adv_ref[0, :] + val_now


@functools.partial(jax.jit, static_argnames=("gamma", "lam"))
def gae_scan(
    rew: jax.Array, val: jax.Array, cont: jax.Array, gamma: float, lam: float
):
    """Pallas GAE. rew:[T], val:[T+1], cont:[T] -> (adv[T], ret[T])."""
    (t_len,) = rew.shape
    assert val.shape == (t_len + 1,), (val.shape, t_len)
    assert cont.shape == (t_len,)

    out = pl.pallas_call(
        functools.partial(_gae_kernel, gamma=gamma, lam=lam, t_len=t_len),
        out_shape=(
            jax.ShapeDtypeStruct((1, t_len), jnp.float32),
            jax.ShapeDtypeStruct((1, t_len), jnp.float32),
        ),
        interpret=_INTERPRET,
    )(rew[None, :], val[None, :], cont[None, :])
    adv, ret = out
    return adv[0], ret[0]
