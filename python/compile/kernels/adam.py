"""L1 Pallas kernel: fused Adam step over the flat parameter vector.

The WALL-E learner keeps all network parameters as one flat f32[P] buffer
(the flat-parameter ABI, DESIGN.md §2), so the optimizer update is a single
element-wise kernel over four P-length arrays:

    m' = b1*m + (1-b1)*g
    v' = b2*v + (1-b2)*g^2
    p' = p - lr * (m'/(1-b1^t)) / (sqrt(v'/(1-b2^t)) + eps)

Pure VPU work: the grid blocks P into (8, 128)-aligned [1, BP] tiles; every
tile is read once and written once (three outputs), so the kernel is
bandwidth-bound at exactly 7 P-vectors of HBM traffic — the roofline for
this op. ``t`` and ``lr`` arrive as [1,1] arrays broadcast to every block
(runtime inputs so the coordinator can anneal the learning rate without
re-compiling).

Oracle: ``ref.adam_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INTERPRET = True  # CPU image — see fused_linear.py

DEF_BLOCK_P = 8 * 128 * 8  # 8192 elements/tile


def _adam_kernel(p_ref, m_ref, v_ref, g_ref, t_ref, lr_ref, po_ref, mo_ref, vo_ref,
                 *, beta1, beta2, eps):
    g = g_ref[...]
    t = t_ref[0, 0]
    lr = lr_ref[0, 0]
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mhat = m_new / (1.0 - beta1**t)
    vhat = v_new / (1.0 - beta2**t)
    po_ref[...] = p_ref[...] - lr * mhat / (jnp.sqrt(vhat) + eps)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


@functools.partial(
    jax.jit, static_argnames=("beta1", "beta2", "eps", "block_p")
)
def adam_step(
    p: jax.Array,
    m: jax.Array,
    v: jax.Array,
    g: jax.Array,
    t: jax.Array,
    lr: jax.Array,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    block_p: int = DEF_BLOCK_P,
):
    """Fused Adam over flat f32[P] arrays; t, lr are f32 scalars (1-based t)."""
    (pn,) = p.shape
    bp = min(block_p, ((pn + 127) // 128) * 128)
    pad = (-pn) % bp
    padded = [jnp.pad(a, (0, pad))[None, :] for a in (p, m, v, g)]
    np_ = pn + pad
    grid = (np_ // bp,)

    spec = pl.BlockSpec((1, bp), lambda i: (0, i))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    outs = pl.pallas_call(
        functools.partial(_adam_kernel, beta1=beta1, beta2=beta2, eps=eps),
        grid=grid,
        in_specs=[spec, spec, spec, spec, scalar_spec, scalar_spec],
        out_specs=(spec, spec, spec),
        out_shape=tuple(
            jax.ShapeDtypeStruct((1, np_), jnp.float32) for _ in range(3)
        ),
        interpret=_INTERPRET,
    )(*padded, jnp.reshape(t, (1, 1)), jnp.reshape(lr, (1, 1)))
    p_new, m_new, v_new = (o[0, :pn] for o in outs)
    return p_new, m_new, v_new
