"""L1 Pallas kernels for the WALL-E compute hot path.

``fused_linear`` — tiled matmul + bias + activation with a Pallas backward;
``gae_scan``     — reverse-time generalized advantage estimation;
``adam_step``    — fused optimizer update over the flat parameter vector;
``ref``          — pure-jnp oracles for all of the above.
"""

from .fused_linear import fused_linear, fused_linear_fwd_impl, matmul
from .gae import gae_scan
from .adam import adam_step
from . import ref

__all__ = [
    "fused_linear",
    "fused_linear_fwd_impl",
    "matmul",
    "gae_scan",
    "adam_step",
    "ref",
]
