"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an exact pure-`jax.numpy`
counterpart here. ``python/tests/test_kernels.py`` sweeps shapes / dtypes
with hypothesis and asserts ``allclose(kernel, ref)``. The refs are also
what the Rust ``NativeBackend`` mirrors (see ``rust/src/nn/``), so the three
implementations (Pallas, jnp, Rust) triangulate each other.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

ACTIVATIONS = ("id", "tanh", "relu")


def apply_activation(y: jax.Array, activation: str) -> jax.Array:
    """Apply one of the supported fused activations."""
    if activation == "id":
        return y
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    raise ValueError(f"unknown activation {activation!r}")


def activation_grad_from_out(y: jax.Array, activation: str) -> jax.Array:
    """d(act)/d(pre-activation), expressed in terms of the *output* y.

    This is the form the backward kernel uses so the forward does not have
    to stash pre-activations: tanh' = 1 - y^2, relu' = 1[y > 0], id' = 1.
    """
    if activation == "id":
        return jnp.ones_like(y)
    if activation == "tanh":
        return 1.0 - y * y
    if activation == "relu":
        return (y > 0.0).astype(y.dtype)
    raise ValueError(f"unknown activation {activation!r}")


# ---------------------------------------------------------------------------
# fused linear
# ---------------------------------------------------------------------------


def linear_ref(
    x: jax.Array, w: jax.Array, b: jax.Array | None, activation: str = "id"
) -> jax.Array:
    """Reference for kernels.fused_linear: act(x @ w + b).

    x: [M, K], w: [K, N], b: [N] or None -> [M, N].
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b[None, :]
    return apply_activation(y, activation).astype(x.dtype)


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain matmul reference (the bias-free / identity case)."""
    return linear_ref(x, w, None, "id")


def linear_bwd_ref(
    x: jax.Array, w: jax.Array, y: jax.Array, dy: jax.Array, activation: str
):
    """Reference backward for the fused linear layer.

    Returns (dx, dw, db) given output y and cotangent dy.
    """
    dz = dy * activation_grad_from_out(y, activation)
    dx = jnp.dot(dz, w.T, preferred_element_type=jnp.float32).astype(x.dtype)
    dw = jnp.dot(x.T, dz, preferred_element_type=jnp.float32).astype(w.dtype)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


# ---------------------------------------------------------------------------
# GAE (generalized advantage estimation)
# ---------------------------------------------------------------------------


def gae_ref(
    rew: jax.Array,
    val: jax.Array,
    cont: jax.Array,
    gamma: float,
    lam: float,
):
    """Reference for kernels.gae_scan.

    rew:  [T]   rewards r_t
    val:  [T+1] value estimates V(s_0..s_T) (bootstrap value last)
    cont: [T]   1.0 if the episode continues after step t, else 0.0
    Returns (adv[T], ret[T]) with
        delta_t = r_t + gamma * cont_t * V_{t+1} - V_t
        adv_t   = delta_t + gamma * lam * cont_t * adv_{t+1}
        ret_t   = adv_t + V_t
    """
    T = rew.shape[0]
    delta = rew + gamma * cont * val[1:] - val[:-1]

    def step(carry, xs):
        d, c = xs
        a = d + gamma * lam * c * carry
        return a, a

    _, adv_rev = jax.lax.scan(
        step, jnp.zeros((), rew.dtype), (delta[::-1], cont[::-1])
    )
    adv = adv_rev[::-1]
    ret = adv + val[:-1]
    return adv, ret


def gae_ref_py(rew, val, cont, gamma, lam):
    """Plain-python GAE for testing the jnp ref itself (and the Rust port)."""
    T = len(rew)
    adv = [0.0] * T
    last = 0.0
    for t in range(T - 1, -1, -1):
        delta = rew[t] + gamma * cont[t] * val[t + 1] - val[t]
        last = delta + gamma * lam * cont[t] * last
        adv[t] = last
    ret = [a + v for a, v in zip(adv, val[:T])]
    return adv, ret


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_ref(
    p: jax.Array,
    m: jax.Array,
    v: jax.Array,
    g: jax.Array,
    t: jax.Array,
    lr: jax.Array,
    beta1: float,
    beta2: float,
    eps: float,
):
    """Reference for kernels.adam_step (t is the 1-based step counter)."""
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    mhat = m_new / (1.0 - beta1**t)
    vhat = v_new / (1.0 - beta2**t)
    p_new = p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p_new, m_new, v_new
