"""WALL-E build-time compile path (L2 JAX model + L1 Pallas kernels).

This package runs ONLY at ``make artifacts``: it lowers the model entry
points to HLO text that the Rust coordinator loads via PJRT. It is never
imported at request time.
"""
