"""L2 JAX model: WALL-E policy/value networks, PPO and DDPG update rules.

Everything here is authored against the **flat-parameter ABI** (DESIGN.md
§2): each network's parameters live in one flat ``f32[P]`` vector that the
Rust coordinator owns, checkpoints, and ships through the policy queue.
The layout (name/shape/offset per tensor) is produced by
:func:`param_spec` and exported to ``meta.json`` by ``aot.py`` so both
sides agree byte-for-byte.

All dense compute goes through the L1 Pallas ``fused_linear`` kernel
(forward *and* backward via its custom VJP); the optimizer is the L1
``adam_step`` kernel; GAE is the L1 ``gae_scan`` kernel. This module is
therefore thin glue: distributions, losses, and parameter bookkeeping.

Networks (paper-era PPO defaults):
  * policy  pi : obs -> tanh MLP (64, 64) -> mean[A]; state-independent
    ``log_std[A]`` as a free parameter; diagonal Gaussian.
  * value   vf : obs -> tanh MLP (64, 64) -> V(s).
  * DDPG actor : obs -> relu MLP -> tanh -> action in [-1, 1]^A.
  * DDPG critic: concat(obs, act) -> relu MLP -> Q(s, a).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import adam_step, fused_linear

LOG_2PI = math.log(2.0 * math.pi)


# ---------------------------------------------------------------------------
# flat-parameter ABI
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamEntry:
    """One tensor inside a flat parameter vector."""

    name: str
    shape: Tuple[int, ...]
    offset: int
    init: str  # "glorot" | "zeros" | "const:<v>"

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "offset": self.offset,
            "size": self.size,
            "init": self.init,
        }


def _mlp_entries(
    prefix: str,
    in_dim: int,
    hidden: Sequence[int],
    out_dim: int,
    offset: int,
) -> Tuple[List[ParamEntry], int]:
    entries: List[ParamEntry] = []
    dims = [in_dim, *hidden, out_dim]
    for i, (fi, fo) in enumerate(zip(dims[:-1], dims[1:])):
        name = f"{prefix}/l{i}" if i < len(hidden) else f"{prefix}/out"
        entries.append(ParamEntry(f"{name}/w", (fi, fo), offset, "glorot"))
        offset += fi * fo
        entries.append(ParamEntry(f"{name}/b", (fo,), offset, "zeros"))
        offset += fo
    return entries, offset


def param_spec(
    obs_dim: int, act_dim: int, hidden: Sequence[int] = (64, 64)
) -> List[ParamEntry]:
    """Layout of the PPO flat vector: policy MLP, log_std, value MLP."""
    entries, off = _mlp_entries("pi", obs_dim, hidden, act_dim, 0)
    entries.append(ParamEntry("pi/log_std", (act_dim,), off, "const:-0.5"))
    off += act_dim
    vf, off = _mlp_entries("vf", obs_dim, hidden, 1, off)
    return entries + vf


def actor_spec(
    obs_dim: int, act_dim: int, hidden: Sequence[int] = (64, 64)
) -> List[ParamEntry]:
    """Layout of the DDPG actor flat vector."""
    entries, _ = _mlp_entries("actor", obs_dim, hidden, act_dim, 0)
    return entries


def critic_spec(
    obs_dim: int, act_dim: int, hidden: Sequence[int] = (64, 64)
) -> List[ParamEntry]:
    """Layout of the DDPG critic flat vector (input = concat(obs, act))."""
    entries, _ = _mlp_entries("critic", obs_dim + act_dim, hidden, 1, 0)
    return entries


def flat_size(spec: Sequence[ParamEntry]) -> int:
    return sum(e.size for e in spec)


def unflatten(flat: jax.Array, spec: Sequence[ParamEntry]) -> Dict[str, jax.Array]:
    """Slice a flat f32[P] vector into named, shaped tensors."""
    out = {}
    for e in spec:
        out[e.name] = jax.lax.dynamic_slice(flat, (e.offset,), (e.size,)).reshape(
            e.shape
        )
    return out


def init_flat(spec: Sequence[ParamEntry], key: jax.Array) -> jax.Array:
    """Glorot-uniform / zeros / const init — mirrors rust runtime::params."""
    chunks = []
    for e in spec:
        key, sub = jax.random.split(key)
        if e.init == "glorot":
            fi, fo = e.shape
            bound = math.sqrt(6.0 / (fi + fo))
            chunks.append(
                jax.random.uniform(sub, (e.size,), jnp.float32, -bound, bound)
            )
        elif e.init == "zeros":
            chunks.append(jnp.zeros((e.size,), jnp.float32))
        elif e.init.startswith("const:"):
            chunks.append(jnp.full((e.size,), float(e.init[6:]), jnp.float32))
        else:  # pragma: no cover
            raise ValueError(e.init)
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# forward passes (all dense math = Pallas fused_linear)
# ---------------------------------------------------------------------------


def _mlp(
    p: Dict[str, jax.Array],
    prefix: str,
    x: jax.Array,
    n_hidden: int,
    hidden_act: str,
    out_act: str = "id",
) -> jax.Array:
    for i in range(n_hidden):
        x = fused_linear(x, p[f"{prefix}/l{i}/w"], p[f"{prefix}/l{i}/b"], hidden_act)
    return fused_linear(x, p[f"{prefix}/out/w"], p[f"{prefix}/out/b"], out_act)


def policy_value(
    flat: jax.Array,
    obs: jax.Array,
    spec: Sequence[ParamEntry],
    n_hidden: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(mean[B,A], log_std[A], value[B]) for a batch of observations."""
    p = unflatten(flat, spec)
    mean = _mlp(p, "pi", obs, n_hidden, "tanh")
    value = _mlp(p, "vf", obs, n_hidden, "tanh")[:, 0]
    log_std = p["pi/log_std"]
    return mean, log_std, value


def gaussian_logp(a: jax.Array, mean: jax.Array, log_std: jax.Array) -> jax.Array:
    """Diagonal-Gaussian log-density, summed over the action axis. -> [B]"""
    z = (a - mean) * jnp.exp(-log_std)[None, :]
    return jnp.sum(
        -0.5 * z * z - log_std[None, :] - 0.5 * LOG_2PI, axis=-1
    )


def gaussian_entropy(log_std: jax.Array) -> jax.Array:
    """Entropy of the diagonal Gaussian (state-independent std) -> scalar."""
    return jnp.sum(log_std + 0.5 * (LOG_2PI + 1.0))


def act_fn(
    flat: jax.Array,
    obs: jax.Array,
    noise: jax.Array,
    spec: Sequence[ParamEntry],
    n_hidden: int,
):
    """Sampler entry point. noise ~ N(0,1) is supplied by the Rust RNG so
    the request path is deterministic given the coordinator's seed.

    Returns (action[B,A], logp[B], value[B], mean[B,A])."""
    mean, log_std, value = policy_value(flat, obs, spec, n_hidden)
    std = jnp.exp(log_std)[None, :]
    action = mean + std * noise
    logp = gaussian_logp(action, mean, log_std)
    return action, logp, value, mean


# ---------------------------------------------------------------------------
# PPO
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PpoConfig:
    clip: float = 0.2
    ent_coef: float = 0.0
    vf_coef: float = 0.5
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8


def _masked_mean(x: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def ppo_loss(
    flat: jax.Array,
    obs: jax.Array,
    act: jax.Array,
    old_logp: jax.Array,
    adv: jax.Array,
    ret: jax.Array,
    mask: jax.Array,
    spec: Sequence[ParamEntry],
    n_hidden: int,
    cfg: PpoConfig,
):
    """Clipped-surrogate PPO loss with exact padding masks.

    Returns (total_loss, aux) with aux = (pi_loss, v_loss, entropy,
    approx_kl, clip_frac)."""
    mean, log_std, value = policy_value(flat, obs, spec, n_hidden)
    logp = gaussian_logp(act, mean, log_std)
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - cfg.clip, 1.0 + cfg.clip)
    surr = jnp.minimum(ratio * adv, clipped * adv)
    pi_loss = -_masked_mean(surr, mask)
    v_loss = 0.5 * _masked_mean((value - ret) ** 2, mask)
    entropy = gaussian_entropy(log_std)
    total = pi_loss + cfg.vf_coef * v_loss - cfg.ent_coef * entropy
    approx_kl = _masked_mean(old_logp - logp, mask)
    clip_frac = _masked_mean(
        (jnp.abs(ratio - 1.0) > cfg.clip).astype(jnp.float32), mask
    )
    return total, (pi_loss, v_loss, entropy, approx_kl, clip_frac)


def train_ppo_step(
    flat: jax.Array,
    m: jax.Array,
    v: jax.Array,
    t: jax.Array,
    lr: jax.Array,
    obs: jax.Array,
    act: jax.Array,
    old_logp: jax.Array,
    adv: jax.Array,
    ret: jax.Array,
    mask: jax.Array,
    spec: Sequence[ParamEntry],
    n_hidden: int,
    cfg: PpoConfig,
):
    """One Adam minibatch step. The learner loops this over minibatches and
    epochs; ``t`` is the 1-based global Adam step, ``lr`` the (annealable)
    learning rate.

    Returns (flat', m', v', total, pi_loss, v_loss, entropy, approx_kl,
    clip_frac)."""
    (total, aux), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        flat, obs, act, old_logp, adv, ret, mask, spec, n_hidden, cfg
    )
    flat2, m2, v2 = adam_step(
        flat, m, v, grads, t, lr, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps
    )
    pi_loss, v_loss, entropy, approx_kl, clip_frac = aux
    return flat2, m2, v2, total, pi_loss, v_loss, entropy, approx_kl, clip_frac


# ---------------------------------------------------------------------------
# PPO gradient-only entry (further-work §6.2: parallel policy learning)
# ---------------------------------------------------------------------------


def ppo_grad(
    flat: jax.Array,
    obs: jax.Array,
    act: jax.Array,
    old_logp: jax.Array,
    adv: jax.Array,
    ret: jax.Array,
    mask: jax.Array,
    spec: Sequence[ParamEntry],
    n_hidden: int,
    cfg: PpoConfig,
):
    """Gradient-only variant: lets the Rust coordinator shard a minibatch
    across several learner threads and average gradients before one Adam
    step (data-parallel policy learning — the paper's §6 item 2).

    Returns (grads[P], total, n_valid) where n_valid = sum(mask) so the
    coordinator can do an exact weighted average of shard gradients."""
    (total, _aux), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        flat, obs, act, old_logp, adv, ret, mask, spec, n_hidden, cfg
    )
    return grads, total, jnp.sum(mask)


def apply_grads(
    flat: jax.Array,
    m: jax.Array,
    v: jax.Array,
    grads: jax.Array,
    t: jax.Array,
    lr: jax.Array,
    cfg: PpoConfig,
):
    """Adam application for pre-averaged gradients (pairs with ppo_grad)."""
    return adam_step(
        flat, m, v, grads, t, lr, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps
    )


# ---------------------------------------------------------------------------
# DDPG (further-work §6.1: off-policy + replay, parallel collection)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DdpgConfig:
    gamma: float = 0.99
    tau: float = 0.005
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8


def ddpg_actor_forward(
    actor_flat: jax.Array,
    obs: jax.Array,
    aspec: Sequence[ParamEntry],
    n_hidden: int,
) -> jax.Array:
    """Deterministic actor: tanh-squashed action in [-1, 1]^A."""
    p = unflatten(actor_flat, aspec)
    return _mlp(p, "actor", obs, n_hidden, "relu", out_act="tanh")


def ddpg_critic_forward(
    critic_flat: jax.Array,
    obs: jax.Array,
    act: jax.Array,
    cspec: Sequence[ParamEntry],
    n_hidden: int,
) -> jax.Array:
    p = unflatten(critic_flat, cspec)
    x = jnp.concatenate([obs, act], axis=-1)
    return _mlp(p, "critic", x, n_hidden, "relu")[:, 0]


def train_ddpg_step(
    actor: jax.Array,
    critic: jax.Array,
    targ_actor: jax.Array,
    targ_critic: jax.Array,
    am: jax.Array,
    av: jax.Array,
    cm: jax.Array,
    cv: jax.Array,
    t: jax.Array,
    lr_a: jax.Array,
    lr_c: jax.Array,
    obs: jax.Array,
    act: jax.Array,
    rew: jax.Array,
    next_obs: jax.Array,
    done: jax.Array,
    aspec: Sequence[ParamEntry],
    cspec: Sequence[ParamEntry],
    n_hidden: int,
    cfg: DdpgConfig,
):
    """One fused DDPG update: critic TD step, actor DPG step, Polyak targets.

    Returns (actor', critic', targ_actor', targ_critic', am', av', cm',
    cv', q_loss, pi_loss)."""
    # --- critic: TD(0) target from the *target* networks
    next_a = ddpg_actor_forward(targ_actor, next_obs, aspec, n_hidden)
    q_next = ddpg_critic_forward(targ_critic, next_obs, next_a, cspec, n_hidden)
    target = rew + cfg.gamma * (1.0 - done) * q_next
    target = jax.lax.stop_gradient(target)

    def critic_loss(cflat):
        q = ddpg_critic_forward(cflat, obs, act, cspec, n_hidden)
        return jnp.mean((q - target) ** 2)

    q_loss, cgrads = jax.value_and_grad(critic_loss)(critic)
    critic2, cm2, cv2 = adam_step(
        critic, cm, cv, cgrads, t, lr_c, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps
    )

    # --- actor: deterministic policy gradient through the *updated* critic
    def actor_loss(aflat):
        a = ddpg_actor_forward(aflat, obs, aspec, n_hidden)
        return -jnp.mean(ddpg_critic_forward(critic2, obs, a, cspec, n_hidden))

    pi_loss, agrads = jax.value_and_grad(actor_loss)(actor)
    actor2, am2, av2 = adam_step(
        actor, am, av, agrads, t, lr_a, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps
    )

    # --- Polyak soft target updates
    targ_actor2 = (1.0 - cfg.tau) * targ_actor + cfg.tau * actor2
    targ_critic2 = (1.0 - cfg.tau) * targ_critic + cfg.tau * critic2

    return (
        actor2,
        critic2,
        targ_actor2,
        targ_critic2,
        am2,
        av2,
        cm2,
        cv2,
        q_loss,
        pi_loss,
    )
