"""AOT lowering: JAX/Pallas model entry points -> HLO *text* artifacts.

Run once by ``make artifacts`` (never at request time):

    cd python && python -m compile.aot --out-dir ../artifacts

For every env preset this emits shape-specialized HLO text files plus a
``meta.json`` describing the flat-parameter layout, batch shapes and baked
hyper-parameters — everything the Rust runtime needs to initialize
parameters and validate calls without parsing HLO.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import gae as gae_kernel


# ---------------------------------------------------------------------------
# env presets (shape-specialized artifacts per environment)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Preset:
    """Static shapes + baked hyper-parameters for one environment."""

    name: str
    obs_dim: int
    act_dim: int
    hidden: Tuple[int, ...] = (64, 64)
    act_batch: int = 1  # sampler inference batch (1 env per sampler, paper §3)
    # every batch size to emit a shape-specialized ``act`` artifact for:
    # ``act`` covers act_batch, ``act_b{B}`` covers each other B. Rust's
    # runtime picks the exact artifact for its envs-per-sampler M (or the
    # shared-inference fleet size N*M), so the forward is padding-free at
    # any emitted size and pads only between sizes. Shared-inference
    # shards compile the whole ladder and run each dispatch in the
    # smallest bucket that fits its real row count, so the mid-range
    # steps (24, 48, 96) bound the worst-case padding of a straggler-cut
    # partial batch to ~33% instead of 2x, and the large sizes (96, 128)
    # raise the per-shard fleet ceiling without re-sharding.
    act_batches: Tuple[int, ...] = (1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128)
    eval_batch: int = 32  # batched inference artifact for eval / benches
    minibatch: int = 512  # PPO minibatch rows (padded + masked by rust)
    horizon: int = 1024  # GAE artifact T (rust pads shorter trajectories)
    gamma: float = 0.99
    lam: float = 0.95
    clip: float = 0.2
    ent_coef: float = 0.0
    vf_coef: float = 0.5
    lr: float = 3e-4  # default; runtime input anneals it
    ddpg: bool = False
    ddpg_batch: int = 256
    ddpg_gamma: float = 0.99
    ddpg_tau: float = 0.005
    parallel_learn: bool = False  # emit ppo_grad/apply_grads (§6.2 ablation)


PRESETS: Dict[str, Preset] = {
    p.name: p
    for p in [
        Preset("pendulum", obs_dim=3, act_dim=1, minibatch=256, horizon=256,
               ddpg=True),
        Preset("cartpole", obs_dim=4, act_dim=1, minibatch=256, horizon=512),
        Preset("reacher", obs_dim=10, act_dim=2, minibatch=256, horizon=128),
        Preset("halfcheetah", obs_dim=17, act_dim=6, minibatch=512,
               horizon=1024, ddpg=True, parallel_learn=True),
    ]
}


# ---------------------------------------------------------------------------
# HLO text emission
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the rust-loadable format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(*shape: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_entry(fn: Callable, example_args: Sequence) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


# ---------------------------------------------------------------------------
# per-preset entry points
# ---------------------------------------------------------------------------


def build_entries(p: Preset) -> Dict[str, Tuple[Callable, List]]:
    """Map artifact name -> (jax function, example args) for one preset."""
    spec = model.param_spec(p.obs_dim, p.act_dim, p.hidden)
    P = model.flat_size(spec)
    nh = len(p.hidden)
    O, A, M, T = p.obs_dim, p.act_dim, p.minibatch, p.horizon
    cfg = model.PpoConfig(clip=p.clip, ent_coef=p.ent_coef, vf_coef=p.vf_coef)

    def act(flat, obs, noise):
        return model.act_fn(flat, obs, noise, spec, nh)

    def train_ppo(flat, m, v, t, lr, obs, a, old_logp, adv, ret, mask):
        return model.train_ppo_step(
            flat, m, v, t, lr, obs, a, old_logp, adv, ret, mask, spec, nh, cfg
        )

    def gae(rew, val, cont):
        return gae_kernel.gae_scan(rew, val, cont, p.gamma, p.lam)

    entries: Dict[str, Tuple[Callable, List]] = {
        "act": (act, [_f32(P), _f32(p.act_batch, O), _f32(p.act_batch, A)]),
        "act_eval": (act, [_f32(P), _f32(p.eval_batch, O), _f32(p.eval_batch, A)]),
        **{
            f"act_b{b}": (act, [_f32(P), _f32(b, O), _f32(b, A)])
            for b in p.act_batches
            if b != p.act_batch
        },
        "train_ppo": (
            train_ppo,
            [_f32(P), _f32(P), _f32(P), _f32(), _f32(),
             _f32(M, O), _f32(M, A), _f32(M), _f32(M), _f32(M), _f32(M)],
        ),
        "gae": (gae, [_f32(T), _f32(T + 1), _f32(T)]),
    }

    if p.parallel_learn:
        def grad_ppo(flat, obs, a, old_logp, adv, ret, mask):
            return model.ppo_grad(
                flat, obs, a, old_logp, adv, ret, mask, spec, nh, cfg
            )

        def apply_grads(flat, m, v, g, t, lr):
            return model.apply_grads(flat, m, v, g, t, lr, cfg)

        entries["grad_ppo"] = (
            grad_ppo,
            [_f32(P), _f32(M, O), _f32(M, A), _f32(M), _f32(M), _f32(M), _f32(M)],
        )
        entries["apply_grads"] = (
            apply_grads,
            [_f32(P), _f32(P), _f32(P), _f32(P), _f32(), _f32()],
        )

    if p.ddpg:
        aspec = model.actor_spec(O, A, p.hidden)
        cspec = model.critic_spec(O, A, p.hidden)
        Pa, Pc = model.flat_size(aspec), model.flat_size(cspec)
        B = p.ddpg_batch
        dcfg = model.DdpgConfig(gamma=p.ddpg_gamma, tau=p.ddpg_tau)

        def act_ddpg(actor, obs):
            return (model.ddpg_actor_forward(actor, obs, aspec, nh),)

        def train_ddpg(actor, critic, ta, tc, am, av, cm, cv, t, lra, lrc,
                       obs, a, rew, next_obs, done):
            return model.train_ddpg_step(
                actor, critic, ta, tc, am, av, cm, cv, t, lra, lrc,
                obs, a, rew, next_obs, done, aspec, cspec, nh, dcfg,
            )

        entries["act_ddpg"] = (act_ddpg, [_f32(Pa), _f32(p.act_batch, O)])
        for b in p.act_batches:
            if b != p.act_batch:
                entries[f"act_ddpg_b{b}"] = (act_ddpg, [_f32(Pa), _f32(b, O)])
        entries["train_ddpg"] = (
            train_ddpg,
            [_f32(Pa), _f32(Pc), _f32(Pa), _f32(Pc),
             _f32(Pa), _f32(Pa), _f32(Pc), _f32(Pc),
             _f32(), _f32(), _f32(),
             _f32(B, O), _f32(B, A), _f32(B), _f32(B, O), _f32(B)],
        )

    return entries


def preset_meta(p: Preset, artifacts: Dict[str, str]) -> dict:
    spec = model.param_spec(p.obs_dim, p.act_dim, p.hidden)
    meta = {
        "preset": p.name,
        "obs_dim": p.obs_dim,
        "act_dim": p.act_dim,
        "hidden": list(p.hidden),
        "act_batch": p.act_batch,
        "act_batches": sorted(set(p.act_batches) | {p.act_batch}),
        "eval_batch": p.eval_batch,
        "minibatch": p.minibatch,
        "horizon": p.horizon,
        "gamma": p.gamma,
        "lam": p.lam,
        "clip": p.clip,
        "ent_coef": p.ent_coef,
        "vf_coef": p.vf_coef,
        "lr": p.lr,
        "param_count": model.flat_size(spec),
        "params": [e.to_json() for e in spec],
        "artifacts": artifacts,
    }
    if p.ddpg:
        aspec = model.actor_spec(p.obs_dim, p.act_dim, p.hidden)
        cspec = model.critic_spec(p.obs_dim, p.act_dim, p.hidden)
        meta["ddpg"] = {
            "batch": p.ddpg_batch,
            "gamma": p.ddpg_gamma,
            "tau": p.ddpg_tau,
            "actor_count": model.flat_size(aspec),
            "critic_count": model.flat_size(cspec),
            "actor_params": [e.to_json() for e in aspec],
            "critic_params": [e.to_json() for e in cspec],
        }
    return meta


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def emit_preset(p: Preset, out_dir: str, only: set | None = None) -> dict:
    pdir = os.path.join(out_dir, p.name)
    os.makedirs(pdir, exist_ok=True)
    artifacts = {}
    for name, (fn, args) in build_entries(p).items():
        if only and name not in only:
            continue
        t0 = time.time()
        text = lower_entry(fn, args)
        rel = f"{p.name}/{name}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        artifacts[name] = rel
        print(f"  {rel}: {len(text)} chars ({time.time() - t0:.1f}s)")
    meta = preset_meta(p, artifacts)
    with open(os.path.join(pdir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--presets", default=",".join(PRESETS),
        help="comma-separated preset names",
    )
    ap.add_argument("--entries", default="", help="only emit these entries")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.entries.split(",")) if args.entries else None
    index = {}
    for name in args.presets.split(","):
        p = PRESETS[name]
        print(f"preset {name} (obs={p.obs_dim} act={p.act_dim})")
        meta = emit_preset(p, args.out_dir, only)
        index[name] = {
            "dir": name,
            "param_count": meta["param_count"],
            "artifacts": meta["artifacts"],
        }
    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"wrote {args.out_dir}/index.json ({len(index)} presets)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
