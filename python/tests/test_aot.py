"""AOT emission tests: HLO text well-formedness, meta.json consistency, and
an execute-what-we-emit round trip through the XLA CPU client (the same
engine the Rust PJRT runtime uses)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def pendulum_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.emit_preset(aot.PRESETS["pendulum"], out)
    return out


class TestEmission:
    def test_all_presets_registered(self):
        assert set(aot.PRESETS) == {"pendulum", "cartpole", "reacher", "halfcheetah"}

    def test_entries_cover_required_set(self):
        for name, p in aot.PRESETS.items():
            entries = aot.build_entries(p)
            assert {"act", "act_eval", "train_ppo", "gae"} <= set(entries)
            if p.ddpg:
                assert {"act_ddpg", "train_ddpg"} <= set(entries)
            if p.parallel_learn:
                assert {"grad_ppo", "apply_grads"} <= set(entries)

    def test_per_batch_act_entries_emitted(self):
        """One shape-specialized act per Preset.act_batches, so the Rust
        runtime gets a padding-free forward at any emitted M (and the
        shared-inference fleet sizes N*M in between pad minimally)."""
        for name, p in aot.PRESETS.items():
            entries = aot.build_entries(p)
            for b in p.act_batches:
                key = "act" if b == p.act_batch else f"act_b{b}"
                assert key in entries, f"{name}: missing {key}"
                _, args = entries[key]
                assert args[1].shape == (b, p.obs_dim)
                assert args[2].shape == (b, p.act_dim)
                if p.ddpg and b != p.act_batch:
                    dkey = f"act_ddpg_b{b}"
                    assert dkey in entries, f"{name}: missing {dkey}"
                    assert entries[dkey][1][1].shape == (b, p.obs_dim)

    def test_meta_records_act_batches(self):
        p = aot.PRESETS["pendulum"]
        meta = aot.preset_meta(p, {})
        assert meta["act_batches"] == sorted(set(p.act_batches) | {p.act_batch})

    def test_hlo_text_parses(self, pendulum_dir):
        path = os.path.join(pendulum_dir, "pendulum", "act.hlo.txt")
        text = open(path).read()
        assert text.startswith("HloModule")
        # must be loadable by the same parser the rust side uses
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None

    def test_meta_layout_matches_model(self, pendulum_dir):
        meta = json.load(open(os.path.join(pendulum_dir, "pendulum", "meta.json")))
        spec = model.param_spec(meta["obs_dim"], meta["act_dim"], tuple(meta["hidden"]))
        assert meta["param_count"] == model.flat_size(spec)
        for e, j in zip(spec, meta["params"]):
            assert e.name == j["name"]
            assert list(e.shape) == j["shape"]
            assert e.offset == j["offset"]

    def test_meta_artifacts_exist(self, pendulum_dir):
        meta = json.load(open(os.path.join(pendulum_dir, "pendulum", "meta.json")))
        for rel in meta["artifacts"].values():
            assert os.path.exists(os.path.join(pendulum_dir, rel)), rel


class TestProgramShape:
    """Structural round trip: re-parse the emitted HLO text exactly as the
    Rust runtime does (text -> HloModuleProto -> XlaComputation) and verify
    the program signature. Numeric round-trip execution is covered on the
    Rust side (rust/tests/runtime_roundtrip.rs), which is the real consumer
    of these files."""

    def _program_shape(self, path):
        text = open(path).read()
        mod = xc._xla.hlo_module_from_text(text)
        comp = xc._xla.XlaComputation(mod.as_serialized_hlo_module_proto())
        return comp.program_shape()

    def test_act_signature(self, pendulum_dir):
        p = aot.PRESETS["pendulum"]
        spec = model.param_spec(p.obs_dim, p.act_dim, p.hidden)
        ps = self._program_shape(
            os.path.join(pendulum_dir, "pendulum", "act.hlo.txt")
        )
        params = ps.parameter_shapes()
        assert len(params) == 3
        assert params[0].dimensions() == (model.flat_size(spec),)
        assert params[1].dimensions() == (p.act_batch, p.obs_dim)
        assert params[2].dimensions() == (p.act_batch, p.act_dim)
        # return_tuple=True: (action, logp, value, mean)
        result = ps.result_shape()
        assert result.is_tuple() and len(result.tuple_shapes()) == 4

    def test_train_ppo_signature(self, pendulum_dir):
        p = aot.PRESETS["pendulum"]
        spec = model.param_spec(p.obs_dim, p.act_dim, p.hidden)
        P, M = model.flat_size(spec), p.minibatch
        ps = self._program_shape(
            os.path.join(pendulum_dir, "pendulum", "train_ppo.hlo.txt")
        )
        dims = [s.dimensions() for s in ps.parameter_shapes()]
        assert dims == [
            (P,), (P,), (P,), (), (),
            (M, p.obs_dim), (M, p.act_dim), (M,), (M,), (M,), (M,),
        ]
        result = ps.result_shape()
        assert result.is_tuple() and len(result.tuple_shapes()) == 9

    def test_gae_signature(self, pendulum_dir):
        p = aot.PRESETS["pendulum"]
        ps = self._program_shape(
            os.path.join(pendulum_dir, "pendulum", "gae.hlo.txt")
        )
        dims = [s.dimensions() for s in ps.parameter_shapes()]
        assert dims == [(p.horizon,), (p.horizon + 1,), (p.horizon,)]
        result = ps.result_shape()
        assert result.is_tuple() and len(result.tuple_shapes()) == 2
