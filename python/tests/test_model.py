"""L2 model correctness: distributions, losses, update rules, flat-param ABI."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=15, deadline=None)


def key(i):
    return jax.random.PRNGKey(i)


def make(obs_dim=3, act_dim=2, hidden=(16, 16), seed=0):
    spec = model.param_spec(obs_dim, act_dim, hidden)
    flat = model.init_flat(spec, key(seed))
    return spec, flat, len(hidden)


# ---------------------------------------------------------------------------
# flat-parameter ABI
# ---------------------------------------------------------------------------


class TestParamSpec:
    def test_offsets_contiguous(self):
        spec = model.param_spec(17, 6, (64, 64))
        off = 0
        for e in spec:
            assert e.offset == off
            off += e.size
        assert off == model.flat_size(spec)

    def test_expected_layer_names(self):
        spec = model.param_spec(3, 1, (8, 8))
        names = [e.name for e in spec]
        assert names == [
            "pi/l0/w", "pi/l0/b", "pi/l1/w", "pi/l1/b", "pi/out/w", "pi/out/b",
            "pi/log_std",
            "vf/l0/w", "vf/l0/b", "vf/l1/w", "vf/l1/b", "vf/out/w", "vf/out/b",
        ]

    def test_halfcheetah_param_count(self):
        # 17 obs, 6 act, 64x64: documented count the Rust side also asserts
        spec = model.param_spec(17, 6, (64, 64))
        pi = 17 * 64 + 64 + 64 * 64 + 64 + 64 * 6 + 6 + 6
        vf = 17 * 64 + 64 + 64 * 64 + 64 + 64 * 1 + 1
        assert model.flat_size(spec) == pi + vf

    def test_unflatten_round_trip(self):
        spec, flat, _ = make()
        p = model.unflatten(flat, spec)
        rebuilt = jnp.concatenate([p[e.name].reshape(-1) for e in spec])
        np.testing.assert_array_equal(np.array(rebuilt), np.array(flat))

    def test_init_log_std_constant(self):
        spec, flat, _ = make()
        p = model.unflatten(flat, spec)
        np.testing.assert_allclose(np.array(p["pi/log_std"]), -0.5)

    def test_init_glorot_bounds(self):
        spec, flat, _ = make(obs_dim=5, act_dim=3, hidden=(32, 32))
        p = model.unflatten(flat, spec)
        w = np.array(p["pi/l0/w"])
        bound = math.sqrt(6.0 / (5 + 32))
        assert np.all(np.abs(w) <= bound + 1e-6)
        assert np.std(w) > 0.1 * bound  # actually random, not zeros

    def test_actor_critic_specs(self):
        aspec = model.actor_spec(17, 6, (64, 64))
        cspec = model.critic_spec(17, 6, (64, 64))
        assert model.flat_size(aspec) == 17 * 64 + 64 + 64 * 64 + 64 + 64 * 6 + 6
        assert model.flat_size(cspec) == 23 * 64 + 64 + 64 * 64 + 64 + 64 + 1


# ---------------------------------------------------------------------------
# Gaussian policy
# ---------------------------------------------------------------------------


class TestGaussian:
    def test_logp_matches_closed_form(self):
        mean = jnp.array([[0.5, -1.0]])
        log_std = jnp.array([0.1, -0.3])
        a = jnp.array([[0.7, -0.5]])
        got = float(model.gaussian_logp(a, mean, log_std)[0])
        want = 0.0
        for i in range(2):
            s = math.exp(float(log_std[i]))
            z = (float(a[0, i]) - float(mean[0, i])) / s
            want += -0.5 * z * z - float(log_std[i]) - 0.5 * math.log(2 * math.pi)
        assert abs(got - want) < 1e-5

    def test_entropy_closed_form(self):
        log_std = jnp.array([0.0, 0.5])
        got = float(model.gaussian_entropy(log_std))
        want = sum(ls + 0.5 * (math.log(2 * math.pi) + 1) for ls in [0.0, 0.5])
        assert abs(got - want) < 1e-5

    def test_act_fn_zero_noise_is_mean(self):
        spec, flat, nh = make()
        obs = jax.random.normal(key(1), (4, 3))
        noise = jnp.zeros((4, 2))
        action, logp, value, mean = model.act_fn(flat, obs, noise, spec, nh)
        np.testing.assert_allclose(np.array(action), np.array(mean), atol=1e-6)
        assert logp.shape == (4,)
        assert value.shape == (4,)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 1000), batch=st.integers(1, 16))
    def test_act_fn_logp_consistent(self, seed, batch):
        spec, flat, nh = make(seed=seed)
        obs = jax.random.normal(key(seed + 1), (batch, 3))
        noise = jax.random.normal(key(seed + 2), (batch, 2))
        action, logp, _, mean = model.act_fn(flat, obs, noise, spec, nh)
        log_std = model.unflatten(flat, spec)["pi/log_std"]
        want = model.gaussian_logp(action, mean, log_std)
        np.testing.assert_allclose(np.array(logp), np.array(want), atol=1e-5)


# ---------------------------------------------------------------------------
# PPO loss + step
# ---------------------------------------------------------------------------


def ppo_batch(spec, flat, nh, batch=32, seed=0):
    obs = jax.random.normal(key(seed), (batch, 3))
    noise = jax.random.normal(key(seed + 1), (batch, 2))
    action, logp, value, _ = model.act_fn(flat, obs, noise, spec, nh)
    adv = jax.random.normal(key(seed + 2), (batch,))
    ret = value + 0.1 * jax.random.normal(key(seed + 3), (batch,))
    mask = jnp.ones((batch,))
    return obs, action, logp, adv, ret, mask


class TestPpo:
    def test_zero_update_is_neutral(self):
        # With old_logp from the same params, ratio == 1: pi_loss == -mean(adv),
        # kl == 0, clip_frac == 0.
        spec, flat, nh = make()
        cfg = model.PpoConfig()
        obs, act, logp, adv, ret, mask = ppo_batch(spec, flat, nh)
        total, (pi_loss, v_loss, ent, kl, cf) = model.ppo_loss(
            flat, obs, act, logp, adv, ret, mask, spec, nh, cfg
        )
        assert abs(float(kl)) < 1e-5
        assert float(cf) == 0.0
        assert abs(float(pi_loss) + float(jnp.mean(adv))) < 1e-4

    def test_mask_excludes_padding(self):
        spec, flat, nh = make()
        cfg = model.PpoConfig()
        obs, act, logp, adv, ret, mask = ppo_batch(spec, flat, nh, batch=32)
        # poison the padded half with huge values; masked loss must not move
        mask = jnp.concatenate([jnp.ones(16), jnp.zeros(16)])
        adv_poison = adv.at[16:].set(1e6)
        ret_poison = ret.at[16:].set(-1e6)
        t1, _ = model.ppo_loss(
            flat, obs[:16], act[:16], logp[:16], adv[:16], ret[:16],
            jnp.ones(16), spec, nh, cfg,
        )
        t2, _ = model.ppo_loss(
            flat, obs, act, logp, adv_poison, ret_poison, mask, spec, nh, cfg
        )
        assert abs(float(t1) - float(t2)) < 1e-3

    def test_train_step_reduces_value_loss(self):
        spec, flat, nh = make()
        cfg = model.PpoConfig()
        obs, act, logp, adv, ret, mask = ppo_batch(spec, flat, nh, batch=64)
        ret = ret + 1.0  # force a value error to learn away
        m = jnp.zeros_like(flat)
        v = jnp.zeros_like(flat)
        first_v_loss = None
        for t in range(1, 31):
            out = model.train_ppo_step(
                flat, m, v, jnp.float32(t), jnp.float32(1e-2),
                obs, act, logp, adv, ret, mask, spec, nh, cfg,
            )
            flat, m, v = out[0], out[1], out[2]
            v_loss = float(out[5])
            if first_v_loss is None:
                first_v_loss = v_loss
        assert v_loss < 0.5 * first_v_loss

    def test_clip_blocks_large_ratio_gain(self):
        # pi_loss gradient must vanish where ratio is already past the clip
        spec, flat, nh = make()
        cfg = model.PpoConfig(clip=0.2, vf_coef=0.0)
        obs, act, logp, adv, ret, mask = ppo_batch(spec, flat, nh)
        # fake very small old_logp => ratio >> 1+clip for positive adv
        total_hi, (pi_hi, *_rest) = model.ppo_loss(
            flat, obs, act, logp - 5.0, jnp.abs(adv), ret, mask, spec, nh, cfg
        )
        # clipped surrogate == (1+clip)*adv, independent of params
        g = jax.grad(
            lambda f: model.ppo_loss(
                f, obs, act, logp - 5.0, jnp.abs(adv), ret, mask, spec, nh, cfg
            )[0]
        )(flat)
        pi_sl = model.param_spec(3, 2, (16, 16))
        # zero out value-net grads: only policy slice should be ~0 too
        npg = np.array(g)
        pi_size = sum(e.size for e in pi_sl if e.name.startswith("pi/"))
        assert np.abs(npg[:pi_size]).max() < 1e-5

    def test_grad_entry_matches_train_step_direction(self):
        spec, flat, nh = make()
        cfg = model.PpoConfig()
        obs, act, logp, adv, ret, mask = ppo_batch(spec, flat, nh)
        grads, total, n = model.ppo_grad(
            flat, obs, act, logp, adv, ret, mask, spec, nh, cfg
        )
        assert int(n) == 32
        direct = jax.grad(
            lambda f: model.ppo_loss(
                f, obs, act, logp, adv, ret, mask, spec, nh, cfg
            )[0]
        )(flat)
        np.testing.assert_allclose(np.array(grads), np.array(direct), atol=1e-6)


# ---------------------------------------------------------------------------
# DDPG
# ---------------------------------------------------------------------------


class TestDdpg:
    def setup_method(self, _):
        self.O, self.A, self.H = 3, 2, (16, 16)
        self.aspec = model.actor_spec(self.O, self.A, self.H)
        self.cspec = model.critic_spec(self.O, self.A, self.H)
        self.actor = model.init_flat(self.aspec, key(0))
        self.critic = model.init_flat(self.cspec, key(1))
        self.nh = 2

    def test_actor_outputs_bounded(self):
        obs = 10.0 * jax.random.normal(key(2), (16, self.O))
        a = model.ddpg_actor_forward(self.actor, obs, self.aspec, self.nh)
        assert float(jnp.abs(a).max()) <= 1.0

    def test_soft_update_moves_targets(self):
        cfg = model.DdpgConfig(tau=0.5)
        B = 8
        obs = jax.random.normal(key(3), (B, self.O))
        act = jnp.clip(jax.random.normal(key(4), (B, self.A)), -1, 1)
        rew = jax.random.normal(key(5), (B,))
        nxt = jax.random.normal(key(6), (B, self.O))
        done = jnp.zeros((B,))
        ta = jnp.zeros_like(self.actor)
        tc = jnp.zeros_like(self.critic)
        zeros_a = jnp.zeros_like(self.actor)
        zeros_c = jnp.zeros_like(self.critic)
        out = model.train_ddpg_step(
            self.actor, self.critic, ta, tc, zeros_a, zeros_a, zeros_c, zeros_c,
            jnp.float32(1), jnp.float32(1e-3), jnp.float32(1e-3),
            obs, act, rew, nxt, done, self.aspec, self.cspec, self.nh, cfg,
        )
        actor2, critic2, ta2, tc2 = out[0], out[1], out[2], out[3]
        np.testing.assert_allclose(
            np.array(ta2), 0.5 * np.array(actor2), atol=1e-5
        )
        np.testing.assert_allclose(
            np.array(tc2), 0.5 * np.array(critic2), atol=1e-5
        )

    def test_critic_learns_constant_reward(self):
        # rew == 1, done == 1 everywhere: Q target is exactly 1.0
        cfg = model.DdpgConfig()
        B = 64
        obs = jax.random.normal(key(3), (B, self.O))
        act = jnp.clip(jax.random.normal(key(4), (B, self.A)), -1, 1)
        rew = jnp.ones((B,))
        done = jnp.ones((B,))
        actor, critic = self.actor, self.critic
        ta, tc = actor, critic
        am = av = jnp.zeros_like(actor)
        cm = cv = jnp.zeros_like(critic)
        q_first = None
        for t in range(1, 61):
            out = model.train_ddpg_step(
                actor, critic, ta, tc, am, av, cm, cv,
                jnp.float32(t), jnp.float32(0.0), jnp.float32(1e-2),
                obs, act, rew, obs, done, self.aspec, self.cspec, self.nh, cfg,
            )
            actor, critic, ta, tc, am, av, cm, cv, q_loss, _ = out
            if q_first is None:
                q_first = float(q_loss)
        assert float(q_loss) < 0.1 * q_first
