"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, seeds and activations; assert_allclose against
``compile.kernels.ref``. These are the core correctness signal for the
compute hot path (DESIGN.md §5).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    adam_step,
    fused_linear,
    fused_linear_fwd_impl,
    matmul,
    ref,
)
from compile.kernels.gae import gae_scan

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------


class TestFusedLinear:
    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 70),
        k=st.integers(1, 70),
        n=st.integers(1, 70),
        act=st.sampled_from(ref.ACTIVATIONS),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, k, n, act, seed):
        x, w, b = rand(seed, m, k), rand(seed + 1, k, n), rand(seed + 2, n)
        got = fused_linear(x, w, b, act)
        want = ref.linear_ref(x, w, b, act)
        np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5)

    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 40),
        k=st.integers(1, 40),
        n=st.integers(1, 40),
        act=st.sampled_from(ref.ACTIVATIONS),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_grads_match_ref(self, m, k, n, act, seed):
        x, w, b = rand(seed, m, k), rand(seed + 1, k, n), rand(seed + 2, n)
        if act == "relu":
            # avoid measure-zero kink disagreements at exactly 0
            b = b + 0.05
        got = jax.grad(lambda *a: fused_linear(*a, act).sum(), argnums=(0, 1, 2))(
            x, w, b
        )
        want = jax.grad(
            lambda *a: ref.linear_ref(*a, act).sum(), argnums=(0, 1, 2)
        )(x, w, b)
        for g, r in zip(got, want):
            np.testing.assert_allclose(np.array(g), np.array(r), atol=2e-4)

    def test_larger_than_one_block(self):
        # exercise the multi-block grid path (M, K, N all > 128)
        x, w, b = rand(0, 300, 200, scale=0.1), rand(1, 200, 257, scale=0.1), rand(2, 257)
        got = fused_linear(x, w, b, "tanh")
        want = ref.linear_ref(x, w, b, "tanh")
        np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5)

    @pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (16, 128, 256), (128, 256, 128)])
    def test_block_shape_invariance(self, bm, bn, bk):
        x, w, b = rand(0, 100, 190, scale=0.3), rand(1, 190, 70, scale=0.3), rand(2, 70)
        got = fused_linear_fwd_impl(x, w, b, "id", block_m=bm, block_n=bn, block_k=bk)
        want = ref.linear_ref(x, w, b, "id")
        np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-4)

    def test_matmul_helper(self):
        x, w = rand(0, 9, 11), rand(1, 11, 5)
        np.testing.assert_allclose(
            np.array(matmul(x, w)), np.array(ref.matmul_ref(x, w)), atol=1e-5
        )

    def test_single_row_and_column(self):
        x, w, b = rand(0, 1, 3), rand(1, 3, 1), rand(2, 1)
        got = fused_linear(x, w, b, "tanh")
        np.testing.assert_allclose(
            np.array(got), np.array(ref.linear_ref(x, w, b, "tanh")), atol=1e-6
        )

    def test_bwd_formula_ref_consistent(self):
        # linear_bwd_ref must agree with autodiff of linear_ref
        x, w, b = rand(0, 12, 7), rand(1, 7, 9), rand(2, 9)
        y = ref.linear_ref(x, w, b, "tanh")
        dy = rand(3, 12, 9)
        dx, dw, db = ref.linear_bwd_ref(x, w, y, dy, "tanh")
        f = lambda x, w, b: jnp.sum(ref.linear_ref(x, w, b, "tanh") * dy)
        gx, gw, gb = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
        np.testing.assert_allclose(np.array(dx), np.array(gx), atol=1e-5)
        np.testing.assert_allclose(np.array(dw), np.array(gw), atol=1e-5)
        np.testing.assert_allclose(np.array(db), np.array(gb), atol=1e-5)


# ---------------------------------------------------------------------------
# gae_scan
# ---------------------------------------------------------------------------


class TestGae:
    @settings(**SETTINGS)
    @given(
        t=st.integers(1, 300),
        gamma=st.floats(0.5, 1.0),
        lam=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
        p_done=st.floats(0.0, 0.5),
    )
    def test_matches_ref(self, t, gamma, lam, seed, p_done):
        k = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(k, 3)
        rew = jax.random.normal(k1, (t,), jnp.float32)
        val = jax.random.normal(k2, (t + 1,), jnp.float32)
        cont = (jax.random.uniform(k3, (t,)) > p_done).astype(jnp.float32)
        a1, r1 = gae_scan(rew, val, cont, gamma, lam)
        a2, r2 = ref.gae_ref(rew, val, cont, gamma, lam)
        np.testing.assert_allclose(np.array(a1), np.array(a2), atol=1e-4)
        np.testing.assert_allclose(np.array(r1), np.array(r2), atol=1e-4)

    def test_ref_matches_plain_python(self):
        t = 17
        rng = np.random.default_rng(0)
        rew = rng.normal(size=t).astype(np.float32)
        val = rng.normal(size=t + 1).astype(np.float32)
        cont = (rng.random(t) > 0.2).astype(np.float32)
        a1, r1 = ref.gae_ref(jnp.array(rew), jnp.array(val), jnp.array(cont), 0.99, 0.95)
        a2, r2 = ref.gae_ref_py(rew.tolist(), val.tolist(), cont.tolist(), 0.99, 0.95)
        np.testing.assert_allclose(np.array(a1), np.array(a2), atol=1e-4)
        np.testing.assert_allclose(np.array(r1), np.array(r2), atol=1e-4)

    def test_terminal_resets_bootstrap(self):
        # a done at step t must cut the credit flow from t+1
        rew = jnp.array([1.0, 1.0, 1.0], jnp.float32)
        val = jnp.array([0.0, 0.0, 0.0, 100.0], jnp.float32)
        cont = jnp.array([1.0, 1.0, 0.0], jnp.float32)  # terminal at last step
        adv, _ = gae_scan(rew, val, cont, 0.99, 0.95)
        # bootstrap value 100 must not appear anywhere
        assert float(jnp.max(jnp.abs(adv))) < 10.0

    def test_lambda_zero_is_td_residual(self):
        t = 9
        rew = rand(0, t)
        val = rand(1, t + 1)
        cont = jnp.ones((t,), jnp.float32)
        adv, _ = gae_scan(rew, val, cont, 0.9, 0.0)
        delta = rew + 0.9 * val[1:] - val[:-1]
        np.testing.assert_allclose(np.array(adv), np.array(delta), atol=1e-5)


# ---------------------------------------------------------------------------
# adam_step
# ---------------------------------------------------------------------------


class TestAdam:
    @settings(**SETTINGS)
    @given(
        p=st.integers(1, 20000),
        t=st.integers(1, 1000),
        seed=st.integers(0, 2**31 - 1),
        lr=st.floats(1e-5, 1e-2),
    )
    def test_matches_ref(self, p, t, seed, lr):
        par, m, v, g = (rand(seed + i, p) for i in range(4))
        v = jnp.abs(v)  # second moment must be non-negative
        tt, lrr = jnp.float32(t), jnp.float32(lr)
        got = adam_step(par, m, v, g, tt, lrr)
        want = ref.adam_ref(par, m, v, g, tt, lrr, 0.9, 0.999, 1e-8)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.array(a), np.array(b), atol=1e-5)

    def test_zero_grad_keeps_params_nearly_fixed(self):
        p = rand(0, 100)
        m = jnp.zeros(100)
        v = jnp.zeros(100)
        g = jnp.zeros(100)
        p2, m2, v2 = adam_step(p, m, v, g, jnp.float32(1.0), jnp.float32(1e-3))
        np.testing.assert_allclose(np.array(p2), np.array(p), atol=1e-6)
        assert float(jnp.abs(m2).max()) == 0.0
        assert float(jnp.abs(v2).max()) == 0.0

    def test_descends_quadratic(self):
        # 200 adam steps on f(p) = ||p||^2 should shrink the norm a lot
        p = rand(0, 64)
        m = jnp.zeros(64)
        v = jnp.zeros(64)
        start = float(jnp.linalg.norm(p))
        for t in range(1, 201):
            g = 2.0 * p
            p, m, v = adam_step(p, m, v, g, jnp.float32(t), jnp.float32(0.05))
        assert float(jnp.linalg.norm(p)) < 0.2 * start
